//! Dependency-free HTTP/1.1 front-end for the continuous-batching engine.
//!
//! `gq serve --http <addr>` turns the scheduler into a network service
//! without hyper/serde (offline environment): request parsing is
//! hand-rolled over [`std::net::TcpListener`] and bodies use the in-repo
//! [`crate::util::json`] codec.
//!
//! ## Architecture
//!
//! One **engine thread** owns a [`SupervisedEngine`] (the scheduler under
//! `catch_unwind` supervision) and is the only thread that touches the
//! model. Connection threads never decode tokens; they parse HTTP, hand a
//! [`ToEngine::Submit`] message over an mpsc channel, and get back a
//! per-request event channel. The engine loop alternates between draining
//! the submission channel (non-blocking while lanes are active,
//! blocking-parked when idle) and running a supervised step; each step's
//! tokens fan out through the per-request channels, so HTTP consumers
//! observe exactly the greedy tokens the batch engine generated —
//! bit-identical to [`super::engine::generate_scheduled`] regardless of
//! what other requests share the batch.
//!
//! ## Failure model
//!
//! An engine-step panic no longer kills the server: the supervisor
//! attributes the fault (see [`super::supervisor`]) — the poisoned request
//! answers **500** via [`TokenEvent::Failed`], everything else keeps
//! decoding, and unattributable faults restart the engine under a bounded
//! budget. Past the budget `/healthz` flips to **503 engine dead** and the
//! server drains. Requests carry deadlines (`timeout_ms` body field,
//! `ServeConfig::request_timeout_ms`), answered with partial output and
//! `"finish_reason": "timeout"`. Connection threads detect client
//! disconnect (failed SSE chunk write, or a half-closed socket probed
//! between blocking polls) and send [`ToEngine::Cancel`], so an abandoned
//! lane frees its KV pages instead of decoding to completion. The
//! `GQ_FAULT` env (`util::fault`) injects deterministic step panics, NaN
//! logits, engine stalls, slow socket writes/reads, and spurious KV
//! exhaustion for the chaos suite.
//!
//! ## v1 endpoints
//!
//! * `POST /v1/completions` — body `{"prompt": [u32 token ids],
//!   "max_tokens": n, "stream": bool, "timeout_ms": n, "precision": bits}`.
//!   The server binds to a [`super::builder::ModelSet`]: `"precision"`
//!   picks which bank entry decodes the request (omit it, or send 0, for
//!   the server default; an unsupported value answers 400 listing the
//!   supported set). Non-streaming responses return the full token list,
//!   the effective `"precision"`, and per-request metrics; `"stream":
//!   true` switches to chunked transfer encoding carrying SSE events
//!   (`data: {"id":.., "token":..}` per generated token, then a
//!   `"done":true` summary event — which also carries `"precision"` —
//!   then the `data: [DONE]` terminator).
//! * `GET /v1/capabilities` — what this server can do before the first
//!   completion is sent: loaded serving format, supported precisions with
//!   the default and downshift floor, KV dtype, and the active admission
//!   knobs (prefix cache, KV budget, batch/queue caps, request caps).
//! * `GET /metrics` — queue depth, active lanes,
//!   completion/rejection/cancellation/timeout/failure counters (plus
//!   `completed_by_precision`, keyed by bank label), engine restarts, KV
//!   governance gauges (`kv_budget_bytes`, `kv_pressure`, `brownouts`,
//!   `precision_downshifts`, `preemptions`, `shed_predicted_deadline`,
//!   `predicted_wait_ms`), prefix-cache gauges (`prefix_hits`,
//!   `prefill_tokens_saved`, `prefix_cached_pages`), and TTFT /
//!   per-token / queue-wait percentiles over a sliding sample window.
//! * `GET /healthz` — truthful engine liveness (200 `ok` while the engine
//!   thread serves, 503 `engine dead` once the restart budget is spent),
//!   restart count, and the served model's shape.
//!
//! ## Error schema and the legacy fallback
//!
//! Every error status (400/404/405/429/500/503) carries one body shape:
//! `{"error": {"type": .., "message": .., "retry_after_s": n}}`, where
//! `type` is a stable machine-readable tag (`invalid_request`,
//! `overloaded`, `unavailable`, `engine_fault`, ...) and `retry_after_s`
//! is nonzero exactly when a `Retry-After` header accompanies it. Clients
//! written against the pre-v1 plain-string body opt back into it per
//! request with `Accept: application/vnd.gq.v0+json`, which selects the
//! legacy `{"error": "message"}` rendering of the same information.
//!
//! ## Admission control as HTTP semantics
//!
//! The scheduler's back-pressure maps onto status codes — malformed
//! bodies and invalid prompts answer **400**, a draining server answers
//! **503** — and overload walks a ladder from mildest response to
//! harshest (see [`super::scheduler`] for the governance mechanics):
//!
//! 0. **Cache shed** (free): cached-but-unreferenced prefix pages are
//!    trimmed first — no client notices the engine giving back memory
//!    that only made *future* requests faster.
//! 1. **Precision downshift** (live KV above the low watermark, floor
//!    configured): admissions that did not pin a `"precision"` decode at
//!    the floor precision instead — full `max_tokens`, not `degraded`,
//!    visible only in the response's `"precision"` field and the
//!    `precision_downshifts` counter.
//! 2. **Brownout** (pressure persists, or the request pinned its
//!    precision): requests still admit, but with `max_tokens` clamped —
//!    the 200 response carries `"degraded": true` so clients can tell a
//!    voluntary `"length"` finish from a shortened one.
//! 3. **Preemption** (live KV above the high watermark): the supervisor
//!    evicts the youngest lane and requeues it under its original
//!    id/deadline; the client's connection stays open and replayed
//!    tokens are suppressed, so it just looks slower.
//! 4. **Shed** (last resort, the request is never enqueued): a full
//!    admission queue (`ServeConfig::max_queued`), a request whose
//!    worst-case KV cost can never fit under the budget's high
//!    watermark, or a `timeout_ms` already smaller than the predicted
//!    queue wait — each answers **429** with a `Retry-After` computed
//!    from the measured per-step drain rate and queue depth
//!    ([`retry_after_secs`]), never a hardcoded constant.
//!
//! [`HttpServer::shutdown`] stops accepting, then lets the engine drain
//! every in-flight lane before joining it, so accepted requests always
//! complete.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cfg::ServeConfig;
use crate::util::json::Json;
use crate::util::{fault, percentile};

use super::builder::ModelSet;
use super::scheduler::{retry_after_secs, FinishReason, FinishedRequest};
use super::supervisor::SupervisedEngine;

/// Request bodies beyond this are rejected before reading.
const MAX_BODY_BYTES: usize = 1 << 20;
/// Hard cap on the request head (request line + headers): the limited
/// reader turns an endless or oversized header section into EOF — a
/// malformed-request error (400) — instead of unbounded memory growth on
/// the connection thread.
const MAX_HEAD_BYTES: u64 = 16 * 1024;
/// Per-request generation cap; larger `max_tokens` answer 400.
pub const MAX_GEN_TOKENS: usize = 4096;
/// Sliding window for latency percentiles in `/metrics`.
const METRIC_WINDOW: usize = 4096;
/// Hard cap on live connection threads. Past it, new connections are
/// dropped at accept time — OS threads and their stacks are the scarce
/// resource here, and the scheduler's `max_queued` back-pressure can only
/// protect what reaches a parsed request.
const MAX_CONN_THREADS: usize = 256;
/// Socket read/write timeout: a stalled client — one that stops sending a
/// body, or stops reading its response/stream — cannot pin a connection
/// thread forever. A timed-out write errors the handler, which drops the
/// request's event channel; the engine's sends then fail harmlessly.
const IO_TIMEOUT: Duration = Duration::from_secs(60);

/// Connection threads → engine thread.
enum ToEngine {
    Submit {
        prompt: Vec<u32>,
        gen_tokens: usize,
        timeout_ms: Option<u64>,
        /// Requested decode precision (`None`/`Some(0)` = server default;
        /// an explicit bank label is pinned against the downshift rung).
        precision: Option<u8>,
        reply: Sender<SubmitOutcome>,
    },
    /// Client disconnected (or explicitly aborted): evict the request and
    /// free its KV pages.
    Cancel { id: u64 },
    Shutdown,
}

/// Engine thread → the submitting connection thread.
enum SubmitOutcome {
    Accepted { id: u64, events: Receiver<TokenEvent> },
    /// Shed (queue full, KV budget, or predicted-deadline): 429 with a
    /// `Retry-After` derived from the measured drain rate at shed time.
    Overloaded { msg: String, retry_after_secs: u64 },
    Invalid(String),
    ShuttingDown,
    EngineDead,
}

/// Engine thread → a request's streaming consumer.
enum TokenEvent {
    Token(u32),
    Done(FinishedRequest),
    /// The request was killed by an engine fault; maps to HTTP 500.
    Failed(String),
}

#[derive(Default, Clone)]
struct Metrics {
    queued: usize,
    active: usize,
    completed: u64,
    rejected: u64,
    /// Requests evicted by client disconnect or explicit cancel.
    cancelled: u64,
    /// Requests evicted at a deadline (queue or decode).
    timed_out: u64,
    /// Requests killed by an attributed engine fault.
    failed: u64,
    /// Requests shed up front because the predicted queue wait already
    /// exceeded their `timeout_ms` (a subset of `rejected`).
    shed_predicted_deadline: u64,
    /// Supervisor engine restarts (unattributable faults).
    engine_restarts: u64,
    /// Bytes of K/V currently stored across active lanes (gauge).
    kv_bytes: usize,
    /// Bytes of KV page storage held (active lanes + pooled arena pages).
    kv_allocated_bytes: usize,
    /// Live KV bytes over the budget (0.0 with governance off).
    kv_pressure: f64,
    /// Admissions clamped to the brownout token budget.
    brownouts: u64,
    /// Admissions moved to the floor precision under KV pressure.
    precision_downshifts: u64,
    /// Completions per effective decode precision (bank label → count);
    /// the values sum to `completed`.
    completed_by_precision: Vec<(u8, u64)>,
    /// Lanes preempted under KV pressure.
    preemptions: u64,
    /// Admissions that mapped at least one cached prefix chunk.
    prefix_hits: u64,
    /// Prompt positions whose prefill compute was skipped, cumulative.
    prefill_tokens_saved: u64,
    /// KV pages currently held by the prefix cache (gauge).
    prefix_cached_pages: usize,
    /// Predicted queue wait from the measured drain rate (gauge).
    predicted_wait_ms: u64,
    ttft_ms: Vec<f64>,
    token_ms: Vec<f64>,
    queue_wait_ms: Vec<f64>,
}

fn push_capped(v: &mut Vec<f64>, x: f64) {
    if v.len() >= METRIC_WINDOW {
        let excess = v.len() - METRIC_WINDOW / 2;
        v.drain(..excess);
    }
    v.push(x);
}

/// State shared by the engine, accept, and connection threads.
struct Shared {
    shutdown: AtomicBool,
    /// Restart budget exhausted: `/healthz` answers 503 and the engine
    /// loop has exited (new submissions fail as "engine stopped").
    engine_dead: AtomicBool,
    /// Live connection threads (bounded by [`MAX_CONN_THREADS`]).
    conns: AtomicUsize,
    model_name: String,
    vocab: usize,
    max_batch: usize,
    max_queued: usize,
    kv_dtype: &'static str,
    /// KV governance budget (0 = off); static for the server's lifetime.
    kv_budget_bytes: usize,
    /// Loaded serving format (capabilities report).
    format_name: &'static str,
    /// Supported decode precisions (bank labels, ascending).
    precisions: Vec<u8>,
    /// Bank label unspecified requests decode at.
    default_precision: u8,
    /// Downshift floor (0 = rung disabled).
    floor_precision: u8,
    prefix_cache: bool,
    metrics: Mutex<Metrics>,
}

impl Shared {
    fn health_json(&self) -> Json {
        let dead = self.engine_dead.load(Ordering::SeqCst);
        let restarts = self.metrics.lock().unwrap().engine_restarts;
        Json::object()
            .with("status", if dead { "engine dead" } else { "ok" })
            .with("engine_alive", !dead)
            .with("engine_restarts", restarts)
            .with("model", self.model_name.as_str())
            .with("vocab", self.vocab)
    }

    /// `GET /v1/capabilities`: everything a client needs to know before
    /// its first completion — all static for the server's lifetime.
    fn capabilities_json(&self) -> Json {
        let precs: Vec<Json> = self.precisions.iter().map(|&p| Json::from(p as u32)).collect();
        Json::object()
            .with("api", "v1")
            .with("model", self.model_name.as_str())
            .with("format", self.format_name)
            .with("precisions", precs)
            .with("default_precision", self.default_precision as u32)
            .with("precision_floor", self.floor_precision as u32)
            .with("kv_dtype", self.kv_dtype)
            .with("kv_budget_bytes", self.kv_budget_bytes)
            .with("prefix_cache", self.prefix_cache)
            .with("max_batch", self.max_batch)
            .with("max_queued", self.max_queued)
            .with("max_gen_tokens", MAX_GEN_TOKENS)
            .with("max_timeout_ms", MAX_TIMEOUT_MS)
    }

    fn metrics_json(&self) -> Json {
        fn pctl(xs: &[f64]) -> Json {
            Json::object()
                .with("count", xs.len())
                .with("p50", percentile(xs, 50.0))
                .with("p99", percentile(xs, 99.0))
        }
        // Snapshot under the lock (plain memcpys); the percentile sorting
        // over 4096-sample windows happens outside it, so a /metrics
        // poller cannot stall the engine thread's per-step lock takes.
        let m = self.metrics.lock().unwrap().clone();
        let mut by_prec = Json::object();
        let mut pairs = m.completed_by_precision.clone();
        pairs.sort_unstable();
        for (p, c) in pairs {
            by_prec = by_prec.with(&p.to_string(), c);
        }
        Json::object()
            .with("queued", m.queued)
            .with("active", m.active)
            .with("completed", m.completed)
            .with("completed_by_precision", by_prec)
            .with("rejected", m.rejected)
            .with("cancelled", m.cancelled)
            .with("timed_out", m.timed_out)
            .with("failed", m.failed)
            .with("shed_predicted_deadline", m.shed_predicted_deadline)
            .with("engine_restarts", m.engine_restarts)
            .with("connections", self.conns.load(Ordering::SeqCst))
            .with("max_batch", self.max_batch)
            .with("max_queued", self.max_queued)
            .with("kv_dtype", self.kv_dtype)
            .with("kv_bytes", m.kv_bytes)
            .with("kv_allocated_bytes", m.kv_allocated_bytes)
            .with("kv_budget_bytes", self.kv_budget_bytes)
            .with("kv_pressure", m.kv_pressure)
            .with("brownouts", m.brownouts)
            .with("precision_downshifts", m.precision_downshifts)
            .with("preemptions", m.preemptions)
            .with("prefix_hits", m.prefix_hits)
            .with("prefill_tokens_saved", m.prefill_tokens_saved)
            .with("prefix_cached_pages", m.prefix_cached_pages)
            .with("predicted_wait_ms", m.predicted_wait_ms)
            .with("ttft_ms", pctl(&m.ttft_ms))
            .with("token_ms", pctl(&m.token_ms))
            .with("queue_wait_ms", pctl(&m.queue_wait_ms))
    }
}

/// The HTTP serving front-end. Binding spawns the engine thread (scheduler
/// owner) and the accept thread; [`HttpServer::shutdown`] drains both.
pub struct HttpServer {
    addr: SocketAddr,
    tx: Sender<ToEngine>,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    engine: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`; port 0 picks a free port — read
    /// it back from [`HttpServer::local_addr`]) and start serving the
    /// model set under the scheduler knobs in `cfg`. Every precision in
    /// `set` is servable per request; `cfg.default_precision` (0 = the
    /// set's native precision) picks the default and
    /// `cfg.precision_floor` arms the load-adaptive downshift rung.
    pub fn bind(set: Arc<ModelSet>, cfg: ServeConfig, addr: &str) -> Result<HttpServer> {
        let default_prec = set.resolve(cfg.default_precision).context("serve.precision")?;
        let floor_prec = match cfg.precision_floor {
            0 => 0,
            p => set.resolve(p).context("serve.precision_floor")?,
        };
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr().context("reading bound address")?;
        let native = set.native_model();
        let shared = Arc::new(Shared {
            shutdown: AtomicBool::new(false),
            engine_dead: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            model_name: native.cfg.name.clone(),
            vocab: native.cfg.vocab,
            max_batch: cfg.max_batch.max(1),
            max_queued: cfg.max_queued.max(1),
            kv_dtype: cfg.kv_dtype.name(),
            kv_budget_bytes: cfg.kv_budget_bytes,
            format_name: set.format().name(),
            precisions: set.precisions(),
            default_precision: default_prec,
            floor_precision: floor_prec,
            prefix_cache: cfg.prefix_cache,
            metrics: Mutex::new(Metrics::default()),
        });
        let (tx, rx) = mpsc::channel();
        let engine = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("gq-http-engine".into())
                .spawn(move || engine_loop(set, cfg, default_prec, floor_prec, rx, shared))
                .context("spawning engine thread")?
        };
        let accept = {
            let tx = tx.clone();
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("gq-http-accept".into())
                .spawn(move || accept_loop(listener, tx, shared))
                .context("spawning accept thread")?
        };
        Ok(HttpServer { addr, tx, shared, accept: Some(accept), engine: Some(engine) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until the process exits (the accept loop only stops on
    /// [`HttpServer::shutdown`]).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop accepting connections, let the engine drain
    /// every in-flight and queued request (their consumers still receive
    /// all tokens), then join both threads.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        let _ = self.tx.send(ToEngine::Shutdown);
        // Unblock the accept loop: it re-checks the flag per connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Engine thread

fn engine_loop(
    set: Arc<ModelSet>,
    cfg: ServeConfig,
    default_prec: u8,
    floor_prec: u8,
    rx: Receiver<ToEngine>,
    shared: Arc<Shared>,
) {
    let mut engine = SupervisedEngine::with_bank(set.bank(), cfg, default_prec, floor_prec);
    let mut sinks: HashMap<u64, Sender<TokenEvent>> = HashMap::new();
    // Reused scratch for ids whose consumers hung up mid-stream.
    let mut hangups: Vec<u64> = Vec::new();
    let mut draining = false;
    loop {
        if !engine.alive() {
            // Restart budget exhausted. Flip /healthz to 503 and exit: the
            // dropped receiver turns every later submit into a 503 at the
            // connection thread.
            shared.engine_dead.store(true, Ordering::SeqCst);
            publish_gauges(&shared, &engine);
            break;
        }
        if !engine.has_work() {
            if draining {
                break;
            }
            // Idle: park on the channel instead of spinning.
            match rx.recv() {
                Ok(msg) => handle_msg(msg, &mut engine, &mut sinks, &shared, &mut draining),
                Err(_) => break, // server dropped without shutdown()
            }
        }
        loop {
            match rx.try_recv() {
                Ok(msg) => handle_msg(msg, &mut engine, &mut sinks, &shared, &mut draining),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    draining = true;
                    break;
                }
            }
        }
        if !engine.has_work() {
            publish_gauges(&shared, &engine);
            continue;
        }
        let finished = engine.step();
        hangups.clear();
        for &(id, tok) in engine.step_tokens() {
            if let Some(sink) = sinks.get(&id) {
                // A send error means the streaming consumer hung up:
                // cancel the request below so its lane stops decoding and
                // its KV pages return to the arena.
                if sink.send(TokenEvent::Token(tok)).is_err() {
                    hangups.push(id);
                }
            }
        }
        for &id in &hangups {
            if engine.cancel(id).is_some() {
                shared.metrics.lock().unwrap().cancelled += 1;
            }
            sinks.remove(&id);
        }
        publish_gauges(&shared, &engine);
        if !finished.is_empty() {
            let mut m = shared.metrics.lock().unwrap();
            for fr in &finished {
                match fr.finish {
                    FinishReason::Length => {
                        m.completed += 1;
                        match m.completed_by_precision.iter_mut().find(|(p, _)| *p == fr.precision)
                        {
                            Some((_, c)) => *c += 1,
                            None => m.completed_by_precision.push((fr.precision, 1)),
                        }
                        push_capped(&mut m.ttft_ms, fr.metrics.ttft_ms);
                        push_capped(&mut m.queue_wait_ms, fr.metrics.queue_wait_ms);
                        for &t in &fr.metrics.token_ms {
                            push_capped(&mut m.token_ms, t);
                        }
                    }
                    FinishReason::Timeout => m.timed_out += 1,
                    FinishReason::Cancelled => m.cancelled += 1,
                    FinishReason::Failed => m.failed += 1,
                }
            }
            m.engine_restarts = engine.restarts() as u64;
        }
        for fr in finished {
            if let Some(sink) = sinks.remove(&fr.id) {
                let _ = match fr.finish {
                    FinishReason::Failed => sink.send(TokenEvent::Failed(
                        "engine fault while serving this request".to_string(),
                    )),
                    _ => sink.send(TokenEvent::Done(fr)),
                };
            }
        }
    }
}

fn publish_gauges(shared: &Shared, engine: &SupervisedEngine<'_>) {
    let kv_bytes = engine.kv_bytes();
    let kv_allocated = engine.kv_allocated_bytes();
    let kv_pressure = engine.kv_pressure();
    let predicted_wait = engine.predicted_wait_ms();
    let (brownouts, preemptions) = (engine.brownouts(), engine.preemptions());
    let mut m = shared.metrics.lock().unwrap();
    m.queued = engine.queued();
    m.active = engine.active();
    m.kv_bytes = kv_bytes;
    m.kv_allocated_bytes = kv_allocated;
    m.kv_pressure = kv_pressure;
    m.predicted_wait_ms = predicted_wait;
    m.brownouts = brownouts;
    m.precision_downshifts = engine.precision_downshifts();
    m.preemptions = preemptions;
    m.prefix_hits = engine.prefix_hits();
    m.prefill_tokens_saved = engine.prefill_tokens_saved();
    m.prefix_cached_pages = engine.prefix_cached_pages();
    m.engine_restarts = engine.restarts() as u64;
}

fn handle_msg(
    msg: ToEngine,
    engine: &mut SupervisedEngine<'_>,
    sinks: &mut HashMap<u64, Sender<TokenEvent>>,
    shared: &Shared,
    draining: &mut bool,
) {
    match msg {
        ToEngine::Shutdown => *draining = true,
        ToEngine::Cancel { id } => {
            if engine.cancel(id).is_some() {
                shared.metrics.lock().unwrap().cancelled += 1;
            }
            sinks.remove(&id);
        }
        ToEngine::Submit { prompt, gen_tokens, timeout_ms, precision, reply } => {
            // The shed ladder's last rung: all three checks answer 429
            // with the drain-rate-derived Retry-After, before anything
            // is enqueued or allocated.
            let retry = retry_after_secs(engine.predicted_wait_ms());
            if *draining {
                let _ = reply.send(SubmitOutcome::ShuttingDown);
            } else if !engine.alive() {
                let _ = reply.send(SubmitOutcome::EngineDead);
            } else if precision.is_some_and(|p| p != 0 && !engine.precisions().contains(&p)) {
                // An unservable precision is a client bug (400), not
                // overload: check it before the shed ladder so it cannot
                // masquerade as a 429 under pressure.
                let _ = reply.send(SubmitOutcome::Invalid(format!(
                    "precision {} not served (supported: {:?})",
                    precision.unwrap_or(0),
                    engine.precisions()
                )));
            } else if engine.queued() >= shared.max_queued {
                shared.metrics.lock().unwrap().rejected += 1;
                let _ = reply.send(SubmitOutcome::Overloaded {
                    msg: format!(
                        "admission queue full ({} waiting, max_queued = {})",
                        engine.queued(),
                        shared.max_queued
                    ),
                    retry_after_secs: retry,
                });
            } else if engine.kv_submit_refused_for(&prompt, gen_tokens, precision) {
                shared.metrics.lock().unwrap().rejected += 1;
                let _ = reply.send(SubmitOutcome::Overloaded {
                    msg: format!(
                        "kv budget: worst-case cost of {} bytes (prompt {} + max_tokens {}) \
                         cannot be admitted under the budget's high watermark",
                        engine.kv_request_cost_bytes(prompt.len() + gen_tokens),
                        prompt.len(),
                        gen_tokens
                    ),
                    retry_after_secs: retry,
                });
            } else if timeout_ms.is_some_and(|t| t > 0 && engine.predicted_wait_ms() > t) {
                // Deadline-aware shed: admitting a request whose queue
                // wait is already predicted to blow its deadline only
                // burns a timeout later — reject it while it's cheap.
                let mut m = shared.metrics.lock().unwrap();
                m.rejected += 1;
                m.shed_predicted_deadline += 1;
                drop(m);
                let _ = reply.send(SubmitOutcome::Overloaded {
                    msg: format!(
                        "predicted queue wait {} ms exceeds the request deadline {} ms",
                        engine.predicted_wait_ms(),
                        timeout_ms.unwrap_or(0)
                    ),
                    retry_after_secs: retry,
                });
            } else {
                match engine.submit_prec(&prompt, gen_tokens, timeout_ms, precision) {
                    Ok(id) => {
                        let (etx, erx) = mpsc::channel();
                        sinks.insert(id, etx);
                        let _ = reply.send(SubmitOutcome::Accepted { id, events: erx });
                    }
                    Err(e) => {
                        let _ = reply.send(SubmitOutcome::Invalid(e.to_string()));
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Accept + connection threads

/// Decrements the live-connection gauge when a connection thread exits
/// (normally or by panic).
struct ConnGuard(Arc<Shared>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

fn accept_loop(listener: TcpListener, tx: Sender<ToEngine>, shared: Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        // Load-shed past the thread cap by dropping the connection: even a
        // quick 503 write could block the accept loop on a hostile socket.
        if shared.conns.load(Ordering::SeqCst) >= MAX_CONN_THREADS {
            drop(stream);
            continue;
        }
        shared.conns.fetch_add(1, Ordering::SeqCst);
        let tx = tx.clone();
        let conn_shared = shared.clone();
        let spawned = std::thread::Builder::new().name("gq-http-conn".into()).spawn(move || {
            let _guard = ConnGuard(conn_shared.clone());
            handle_conn(stream, tx, conn_shared);
        });
        if spawned.is_err() {
            shared.conns.fetch_sub(1, Ordering::SeqCst);
            crate::log_warn!("http", "failed to spawn connection thread");
        }
    }
}

struct Request {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

/// Parse one HTTP/1.x request (request line, headers, `Content-Length`
/// body). Chunked request bodies are rejected — clients must send a
/// length. `w` carries the interim `100 Continue` response: curl defers
/// bodies over 1 KiB behind `Expect: 100-continue` and would otherwise
/// stall ~1s per large-prompt request waiting for it.
fn read_request(r: &mut impl BufRead, w: &mut impl Write) -> Result<Request> {
    // The head is read through a `Take` so a hostile client cannot grow
    // the line buffers past MAX_HEAD_BYTES; the body keeps its own cap.
    let mut head = r.by_ref().take(MAX_HEAD_BYTES);
    let mut line = String::new();
    if head.read_line(&mut line)? == 0 {
        bail!("empty request");
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let target = parts.next().context("missing request target")?.to_string();
    let version = parts.next().context("missing HTTP version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol `{version}`");
    }
    let path = target.split('?').next().unwrap_or("").to_string();
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        if head.read_line(&mut h)? == 0 {
            bail!("connection closed mid-headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (name, value) =
            h.split_once(':').with_context(|| format!("malformed header `{h}`"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let mut body = Vec::new();
    if let Some(te) = header(&headers, "transfer-encoding") {
        bail!("transfer-encoding `{te}` not supported; send Content-Length");
    }
    if let Some(cl) = header(&headers, "content-length") {
        let n: usize = cl.parse().context("bad Content-Length")?;
        if n > MAX_BODY_BYTES {
            bail!("body too large ({n} bytes, cap {MAX_BODY_BYTES})");
        }
        if let Some(expect) = header(&headers, "expect") {
            if expect.eq_ignore_ascii_case("100-continue") {
                w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").context("writing 100 Continue")?;
                w.flush().context("flushing 100 Continue")?;
            }
        }
        // Chaos site: one slow request-body read (a slowloris-style
        // client trickling its upload); only this connection thread
        // stalls — the engine and its siblings keep serving.
        fault::maybe_stall(fault::SLOW_READ, Duration::from_millis(1000));
        body.resize(n, 0);
        r.read_exact(&mut body).context("reading body")?;
    }
    Ok(Request { method, path, headers, body })
}

fn write_response(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, &str)],
    body: &str,
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {status} {reason}\r\n")?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    for (k, v) in extra {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "Connection: close\r\n\r\n")?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

fn write_json(w: &mut impl Write, status: u16, reason: &str, doc: &Json) -> std::io::Result<()> {
    write_response(w, status, reason, "application/json", &[], &doc.encode())
}

/// Error-body wire format, selected per request from the `Accept` header.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Wire {
    /// v1 (default): structured `{"error": {"type", "message",
    /// "retry_after_s"}}` envelope.
    V1,
    /// Pre-v1 plain-string body `{"error": "message"}`, kept for old
    /// clients behind `Accept: application/vnd.gq.v0+json`.
    V0,
}

fn wire_of(headers: &[(String, String)]) -> Wire {
    match header(headers, "accept") {
        Some(a) if a.contains("application/vnd.gq.v0+json") => Wire::V0,
        _ => Wire::V1,
    }
}

/// One rendering path for every error status: the same `(type, message,
/// retry_after_s)` triple rendered as the v1 envelope or the legacy
/// string. `retry_after_s` is nonzero exactly when the response carries a
/// `Retry-After` header.
fn error_body(wire: Wire, etype: &str, msg: &str, retry_after_s: u64) -> String {
    match wire {
        Wire::V0 => Json::object().with("error", msg).encode(),
        Wire::V1 => Json::object()
            .with(
                "error",
                Json::object()
                    .with("type", etype)
                    .with("message", msg)
                    .with("retry_after_s", retry_after_s),
            )
            .encode(),
    }
}

fn write_error(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    wire: Wire,
    etype: &str,
    msg: &str,
) -> std::io::Result<()> {
    write_response(w, status, reason, "application/json", &[], &error_body(wire, etype, msg, 0))
}

/// The 429 path: the computed Retry-After rides both as the header and as
/// `retry_after_s` inside the v1 envelope.
fn write_error_retry(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    wire: Wire,
    etype: &str,
    retry_after_s: u64,
    msg: &str,
) -> std::io::Result<()> {
    let retry = retry_after_s.to_string();
    write_response(
        w,
        status,
        reason,
        "application/json",
        &[("Retry-After", &retry)],
        &error_body(wire, etype, msg, retry_after_s),
    )
}

fn write_chunk(w: &mut impl Write, payload: &str) -> std::io::Result<()> {
    // Chaos site: one slow SSE chunk write (a stalled client/socket); the
    // engine thread must keep stepping other lanes undisturbed.
    fault::maybe_stall(fault::SLOW_WRITE, Duration::from_millis(1000));
    write!(w, "{:x}\r\n", payload.len())?;
    w.write_all(payload.as_bytes())?;
    w.write_all(b"\r\n")?;
    w.flush()
}

fn finish_chunks(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

fn handle_conn(stream: TcpStream, tx: Sender<ToEngine>, shared: Arc<Shared>) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let req = match read_request(&mut reader, &mut writer) {
        Ok(r) => r,
        Err(e) => {
            // No parsed headers to negotiate against: the v1 envelope is
            // the default wire format.
            let _ =
                write_error(&mut writer, 400, "Bad Request", Wire::V1, "invalid_request", &e.to_string());
            return;
        }
    };
    let wire = wire_of(&req.headers);
    let _ = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let doc = shared.health_json();
            if shared.engine_dead.load(Ordering::SeqCst) {
                write_json(&mut writer, 503, "Service Unavailable", &doc)
            } else {
                write_json(&mut writer, 200, "OK", &doc)
            }
        }
        ("GET", "/metrics") => write_json(&mut writer, 200, "OK", &shared.metrics_json()),
        ("GET", "/v1/capabilities") => {
            write_json(&mut writer, 200, "OK", &shared.capabilities_json())
        }
        ("POST", "/v1/completions") => handle_completion(&mut writer, &req.body, &tx, wire),
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/completions") | (_, "/v1/capabilities") => {
            write_error(
                &mut writer,
                405,
                "Method Not Allowed",
                wire,
                "method_not_allowed",
                &format!("{} not supported on {}", req.method, req.path),
            )
        }
        _ => write_error(
            &mut writer,
            404,
            "Not Found",
            wire,
            "not_found",
            &format!("no route for {} {}", req.method, req.path),
        ),
    };
}

struct CompletionReq {
    prompt: Vec<u32>,
    max_tokens: usize,
    stream: bool,
    /// Per-request wall-clock budget; overrides the server's
    /// `request_timeout_ms` default.
    timeout_ms: Option<u64>,
    /// Requested decode precision in bits (`None`/`Some(0)` = server
    /// default). Validated against the served bank at submit time.
    precision: Option<u8>,
}

/// Longest accepted per-request `timeout_ms` (24h) — anything larger is a
/// client bug, not a deadline.
const MAX_TIMEOUT_MS: u64 = 86_400_000;

fn parse_completion(body: &[u8]) -> Result<CompletionReq> {
    let text = std::str::from_utf8(body).context("body is not UTF-8")?;
    let doc = Json::parse(text)?;
    let prompt = doc.get("prompt").context("missing `prompt` (array of token ids)")?;
    let arr = prompt.as_arr().context("`prompt` must be an array of token ids")?;
    let mut toks = Vec::with_capacity(arr.len());
    for t in arr {
        let n = t.as_u64().context("`prompt` entries must be non-negative integers")?;
        if n > u32::MAX as u64 {
            bail!("prompt token {n} out of range");
        }
        toks.push(n as u32);
    }
    let max_tokens = match doc.get("max_tokens") {
        None => 16,
        Some(m) => {
            let n = m.as_u64().context("`max_tokens` must be a non-negative integer")?;
            // Compare in u64 BEFORE narrowing: `n as usize` would wrap on
            // 32-bit targets and let huge values sail under the cap.
            if n > MAX_GEN_TOKENS as u64 {
                bail!("max_tokens {n} exceeds the per-request cap {MAX_GEN_TOKENS}");
            }
            n as usize
        }
    };
    let stream = match doc.get("stream") {
        None => false,
        Some(s) => s.as_bool().context("`stream` must be a boolean")?,
    };
    let timeout_ms = match doc.get("timeout_ms") {
        None => None,
        Some(t) => {
            let n = t.as_u64().context("`timeout_ms` must be a positive integer")?;
            if n == 0 {
                bail!("`timeout_ms` must be at least 1 (omit it for no deadline)");
            }
            if n > MAX_TIMEOUT_MS {
                bail!("timeout_ms {n} exceeds the cap {MAX_TIMEOUT_MS} (24h)");
            }
            Some(n)
        }
    };
    let precision = match doc.get("precision") {
        None => None,
        Some(p) => {
            let n = p.as_u64().context("`precision` must be a non-negative integer (bits)")?;
            if n > 32 {
                bail!("precision {n} out of range (bits, 0 = server default)");
            }
            Some(n as u8)
        }
    };
    Ok(CompletionReq { prompt: toks, max_tokens, stream, timeout_ms, precision })
}

fn request_metrics_json(fr: &FinishedRequest) -> Json {
    Json::object()
        .with("queue_wait_ms", fr.metrics.queue_wait_ms)
        .with("ttft_ms", fr.metrics.ttft_ms)
        .with("p50_ms", fr.metrics.p50_ms)
        .with("p99_ms", fr.metrics.p99_ms)
        .with("kv_bytes", fr.metrics.kv_bytes)
}

fn handle_completion(
    w: &mut TcpStream,
    body: &[u8],
    tx: &Sender<ToEngine>,
    wire: Wire,
) -> std::io::Result<()> {
    let req = match parse_completion(body) {
        Ok(r) => r,
        Err(e) => return write_error(w, 400, "Bad Request", wire, "invalid_request", &e.to_string()),
    };
    let (rtx, rrx) = mpsc::channel();
    let submit = ToEngine::Submit {
        prompt: req.prompt,
        gen_tokens: req.max_tokens,
        timeout_ms: req.timeout_ms,
        precision: req.precision,
        reply: rtx,
    };
    if tx.send(submit).is_err() {
        return write_error(w, 503, "Service Unavailable", wire, "unavailable", "engine stopped");
    }
    let outcome = match rrx.recv() {
        Ok(o) => o,
        Err(_) => {
            return write_error(w, 503, "Service Unavailable", wire, "unavailable", "engine stopped")
        }
    };
    match outcome {
        SubmitOutcome::Overloaded { msg, retry_after_secs } => write_error_retry(
            w,
            429,
            "Too Many Requests",
            wire,
            "overloaded",
            retry_after_secs,
            &msg,
        ),
        SubmitOutcome::Invalid(msg) => {
            write_error(w, 400, "Bad Request", wire, "invalid_request", &msg)
        }
        SubmitOutcome::ShuttingDown => write_error(
            w,
            503,
            "Service Unavailable",
            wire,
            "unavailable",
            "server is shutting down",
        ),
        SubmitOutcome::EngineDead => write_error(
            w,
            503,
            "Service Unavailable",
            wire,
            "unavailable",
            "engine dead: restart budget exhausted",
        ),
        SubmitOutcome::Accepted { id, events } => {
            if req.stream {
                stream_completion(w, id, events, tx)
            } else {
                blocking_completion(w, id, events, tx, wire)
            }
        }
    }
}

/// Poll interval between client-liveness probes while a blocking
/// completion waits for tokens.
const DISCONNECT_POLL: Duration = Duration::from_millis(250);

/// Has the client half-closed (or reset) the connection? A non-blocking
/// `peek` sees EOF (`Ok(0)`) when the peer sent FIN — a blocking consumer
/// that went away — while `WouldBlock` just means "no bytes, still open".
/// Pipelined request bytes (`Ok(n)`) also count as alive; completions
/// close the connection anyway.
fn client_gone(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let gone = match stream.peek(&mut [0u8; 1]) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    if stream.set_nonblocking(false).is_err() {
        return true;
    }
    gone
}

fn blocking_completion(
    w: &mut TcpStream,
    id: u64,
    events: Receiver<TokenEvent>,
    tx: &Sender<ToEngine>,
    wire: Wire,
) -> std::io::Result<()> {
    loop {
        match events.recv_timeout(DISCONNECT_POLL) {
            Ok(TokenEvent::Token(_)) => continue,
            Ok(TokenEvent::Done(fr)) => {
                let toks: Vec<Json> = fr.tokens.iter().map(|&t| Json::from(t)).collect();
                let doc = Json::object()
                    .with("id", id)
                    .with("tokens", toks)
                    .with("n_tokens", fr.tokens.len())
                    .with("finish_reason", fr.finish.name())
                    .with("precision", fr.precision as u32)
                    .with("degraded", fr.degraded)
                    .with("metrics", request_metrics_json(&fr));
                return write_json(w, 200, "OK", &doc);
            }
            Ok(TokenEvent::Failed(msg)) => {
                return write_error(w, 500, "Internal Server Error", wire, "engine_fault", &msg);
            }
            Err(RecvTimeoutError::Timeout) => {
                // No tokens yet: probe the socket so an abandoned request
                // frees its lane instead of decoding to completion.
                if client_gone(w) {
                    let _ = tx.send(ToEngine::Cancel { id });
                    return Ok(());
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                return write_error(
                    w,
                    500,
                    "Internal Server Error",
                    wire,
                    "engine_fault",
                    "engine dropped request",
                );
            }
        }
    }
}

fn stream_completion(
    w: &mut TcpStream,
    id: u64,
    events: Receiver<TokenEvent>,
    tx: &Sender<ToEngine>,
) -> std::io::Result<()> {
    let res = stream_completion_inner(w, id, &events);
    if res.is_err() {
        // A failed chunk write means the client hung up mid-stream: evict
        // the request so its lane and KV pages are reclaimed. (The engine
        // also detects this via its own failed sends; both paths are
        // idempotent.)
        let _ = tx.send(ToEngine::Cancel { id });
    }
    res
}

fn stream_completion_inner(
    w: &mut TcpStream,
    id: u64,
    events: &Receiver<TokenEvent>,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
         Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    w.flush()?;
    loop {
        match events.recv() {
            Ok(TokenEvent::Token(tok)) => {
                let ev = Json::object().with("id", id).with("token", tok);
                write_chunk(w, &format!("data: {}\n\n", ev.encode()))?;
            }
            Ok(TokenEvent::Done(fr)) => {
                let done = Json::object()
                    .with("id", id)
                    .with("done", true)
                    .with("n_tokens", fr.tokens.len())
                    .with("finish_reason", fr.finish.name())
                    .with("precision", fr.precision as u32)
                    .with("degraded", fr.degraded)
                    .with("metrics", request_metrics_json(&fr));
                write_chunk(w, &format!("data: {}\n\n", done.encode()))?;
                write_chunk(w, "data: [DONE]\n\n")?;
                return finish_chunks(w);
            }
            Ok(TokenEvent::Failed(msg)) => {
                // Mid-stream engine fault: emit an error event and end the
                // stream WITHOUT [DONE] so the client sees truncation.
                let ev = Json::object().with("id", id).with("error", msg.as_str());
                write_chunk(w, &format!("data: {}\n\n", ev.encode()))?;
                return finish_chunks(w);
            }
            // Engine exited without finishing (shutdown drains lanes first,
            // so this is abnormal): end the stream without [DONE] so the
            // client can tell it was truncated.
            Err(_) => return finish_chunks(w),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_bytes(raw: &[u8]) -> Result<Request> {
        let mut r = std::io::BufReader::new(raw);
        read_request(&mut r, &mut Vec::new())
    }

    #[test]
    fn parses_request_line_headers_and_body() {
        let raw = b"POST /v1/completions?x=1 HTTP/1.1\r\nHost: h\r\nContent-Length: 4\r\n\r\nbody";
        let req = parse_bytes(raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions", "query string must be stripped");
        assert_eq!(header(&req.headers, "host"), Some("h"));
        assert_eq!(req.body, b"body");
    }

    #[test]
    fn get_without_body_parses() {
        let req = parse_bytes(b"GET /healthz HTTP/1.0\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
    }

    #[test]
    fn expect_100_continue_gets_interim_response() {
        // curl defers bodies > 1 KiB behind `Expect: 100-continue`; the
        // interim response must be written before the body read.
        let raw = b"POST / HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\nok";
        let mut r = std::io::BufReader::new(&raw[..]);
        let mut interim = Vec::new();
        let req = read_request(&mut r, &mut interim).unwrap();
        assert_eq!(req.body, b"ok");
        assert_eq!(interim, b"HTTP/1.1 100 Continue\r\n\r\n");
        // No Expect header -> nothing interim is written.
        let mut quiet = Vec::new();
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 2\r\n\r\nok";
        read_request(&mut std::io::BufReader::new(&raw[..]), &mut quiet).unwrap();
        assert!(quiet.is_empty());
    }

    #[test]
    fn malformed_requests_error() {
        assert!(parse_bytes(b"").is_err(), "empty request");
        assert!(parse_bytes(b"GET /\r\n\r\n").is_err(), "missing version");
        assert!(parse_bytes(b"GET / SPDY/3\r\n\r\n").is_err(), "bad protocol");
        assert!(parse_bytes(b"GET / HTTP/1.1\r\nbad header\r\n\r\n").is_err());
        assert!(
            parse_bytes(b"POST / HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort").is_err(),
            "truncated body"
        );
        assert!(
            parse_bytes(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n")
                .is_err(),
            "chunked request bodies are unsupported"
        );
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(parse_bytes(huge.as_bytes()).is_err(), "oversized body");
        // An endless header section must hit the MAX_HEAD_BYTES cap, not
        // grow without bound.
        let mut big = String::from("GET / HTTP/1.1\r\n");
        for i in 0..4096 {
            big.push_str(&format!("X-Pad-{i}: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n"));
        }
        big.push_str("\r\n");
        assert!(parse_bytes(big.as_bytes()).is_err(), "oversized header section");
    }

    #[test]
    fn completion_body_validation() {
        let ok = parse_completion(br#"{"prompt": [1, 2, 3]}"#).unwrap();
        assert_eq!(ok.prompt, vec![1, 2, 3]);
        assert_eq!(ok.max_tokens, 16, "default");
        assert!(!ok.stream);
        let full =
            parse_completion(br#"{"prompt": [7], "max_tokens": 0, "stream": true}"#).unwrap();
        assert_eq!(full.max_tokens, 0);
        assert!(full.stream);
        for bad in [
            &b"{oops"[..],
            &br#"{"max_tokens": 4}"#[..],
            &br#"{"prompt": "text"}"#[..],
            &br#"{"prompt": [1.5]}"#[..],
            &br#"{"prompt": [-1]}"#[..],
            &br#"{"prompt": [1], "max_tokens": -2}"#[..],
            &br#"{"prompt": [1], "max_tokens": 99999999}"#[..],
            &br#"{"prompt": [1], "stream": 1}"#[..],
        ] {
            assert!(parse_completion(bad).is_err(), "{:?}", std::str::from_utf8(bad));
        }
    }

    #[test]
    fn completion_timeout_ms_validation() {
        let none = parse_completion(br#"{"prompt": [1]}"#).unwrap();
        assert_eq!(none.timeout_ms, None, "no deadline unless asked");
        let some = parse_completion(br#"{"prompt": [1], "timeout_ms": 1500}"#).unwrap();
        assert_eq!(some.timeout_ms, Some(1500));
        for bad in [
            &br#"{"prompt": [1], "timeout_ms": 0}"#[..],
            &br#"{"prompt": [1], "timeout_ms": -5}"#[..],
            &br#"{"prompt": [1], "timeout_ms": "1s"}"#[..],
            &br#"{"prompt": [1], "timeout_ms": 86400001}"#[..],
        ] {
            assert!(parse_completion(bad).is_err(), "{:?}", std::str::from_utf8(bad));
        }
    }

    #[test]
    fn metric_percentiles_survive_an_empty_window() {
        // A freshly booted server has no samples: /metrics must render
        // quiet zeros, not NaN (which the JSON encoder cannot carry).
        let xs: Vec<f64> = Vec::new();
        assert_eq!(percentile(&xs, 50.0), 0.0);
        assert_eq!(percentile(&xs, 99.0), 0.0);
    }

    #[test]
    fn metric_percentiles_with_a_single_sample() {
        // One completed request: every percentile is that sample.
        let mut xs = Vec::new();
        push_capped(&mut xs, 7.5);
        assert_eq!(percentile(&xs, 50.0), 7.5);
        assert_eq!(percentile(&xs, 99.0), 7.5);
    }

    #[test]
    fn metric_window_wraps_under_sustained_load() {
        // Sustained load far past METRIC_WINDOW: the window must stay
        // bounded, keep insertion order, retain the newest sample, and
        // keep percentiles well-defined over the retained suffix.
        let mut xs = Vec::new();
        let total = METRIC_WINDOW * 3;
        for i in 0..total {
            push_capped(&mut xs, i as f64);
            assert!(xs.len() <= METRIC_WINDOW, "window exceeded its cap at sample {i}");
        }
        assert!(xs.len() > METRIC_WINDOW / 2, "drain must keep the newer half");
        assert_eq!(*xs.last().unwrap(), (total - 1) as f64, "newest sample retained");
        assert!(xs.windows(2).all(|w| w[0] < w[1]), "insertion order preserved");
        assert!(
            *xs.first().unwrap() >= (total - METRIC_WINDOW) as f64,
            "wraparound must drop the oldest samples, not the newest"
        );
        let (p50, p99) = (percentile(&xs, 50.0), percentile(&xs, 99.0));
        assert!(p50 <= p99, "percentiles inverted over the wrapped window");
        assert!(p99 <= (total - 1) as f64 && p50 >= *xs.first().unwrap());
    }

    #[test]
    fn response_writers_produce_wellformed_http() {
        // v1 default: the structured envelope, with the Retry-After value
        // mirrored into the body.
        let mut buf = Vec::new();
        write_error_retry(&mut buf, 429, "Too Many Requests", Wire::V1, "overloaded", 7, "queue full")
            .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Retry-After: 7\r\n"));
        let body =
            "{\"error\":{\"type\":\"overloaded\",\"message\":\"queue full\",\"retry_after_s\":7}}";
        assert!(text.ends_with(body), "{text}");
        assert!(text.contains(&format!("Content-Length: {}\r\n", body.len())));

        // Legacy wire: same information, pre-v1 plain-string body.
        let mut buf = Vec::new();
        write_error(&mut buf, 429, "Too Many Requests", Wire::V0, "overloaded", "queue full")
            .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.ends_with("{\"error\":\"queue full\"}"), "{text}");

        let mut buf = Vec::new();
        write_chunk(&mut buf, "data: hi\n\n").unwrap();
        finish_chunks(&mut buf).unwrap();
        assert_eq!(buf, b"a\r\ndata: hi\n\n\r\n0\r\n\r\n");
    }

    #[test]
    fn accept_header_selects_the_error_wire() {
        let v0 = vec![("accept".to_string(), "application/vnd.gq.v0+json".to_string())];
        assert_eq!(wire_of(&v0), Wire::V0);
        let v1 = vec![("accept".to_string(), "application/json".to_string())];
        assert_eq!(wire_of(&v1), Wire::V1);
        assert_eq!(wire_of(&[]), Wire::V1, "no Accept header means v1");
        // A list mentioning the legacy type anywhere opts in.
        let list =
            vec![("accept".to_string(), "text/html, application/vnd.gq.v0+json".to_string())];
        assert_eq!(wire_of(&list), Wire::V0);
    }

    #[test]
    fn completion_precision_validation() {
        let none = parse_completion(br#"{"prompt": [1]}"#).unwrap();
        assert_eq!(none.precision, None, "absent means server default");
        let zero = parse_completion(br#"{"prompt": [1], "precision": 0}"#).unwrap();
        assert_eq!(zero.precision, Some(0), "0 is the explicit server-default spelling");
        let some = parse_completion(br#"{"prompt": [1], "precision": 2}"#).unwrap();
        assert_eq!(some.precision, Some(2));
        for bad in [
            &br#"{"prompt": [1], "precision": -3}"#[..],
            &br#"{"prompt": [1], "precision": 33}"#[..],
            &br#"{"prompt": [1], "precision": "4bit"}"#[..],
            &br#"{"prompt": [1], "precision": 2.5}"#[..],
        ] {
            assert!(parse_completion(bad).is_err(), "{:?}", std::str::from_utf8(bad));
        }
    }
}
