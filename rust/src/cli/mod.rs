//! Hand-rolled CLI argument parser for the `gq` launcher (clap is not
//! available offline). Supports `--flag value`, `--flag=value`, boolean
//! `--flag`, and positional arguments.
//!
//! Parsing rules worth knowing:
//!
//! * `--flag=` is an **explicit empty value** (kept, retrievable via
//!   [`Args::get`] as `Some("")`) — it is neither dropped nor demoted to a
//!   boolean switch, so typed getters fail loudly on it instead of
//!   silently using their default.
//! * A value that itself starts with `--` must use the `=` form
//!   (`--http=--weird`): in the space-separated form the next `--token` is
//!   always parsed as a flag, never as a value.
//! * `--=value` (empty flag name) is a parse error.
//! * Subcommands can reject typos with [`Args::ensure_known`] instead of
//!   silently ignoring unknown flags.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some(eq) = name.find('=') {
                    if eq == 0 {
                        bail!("empty flag name in `{arg}`");
                    }
                    out.flags.insert(name[..eq].to_string(), name[eq + 1..].to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let val = iter.next().unwrap();
                    out.flags.insert(name.to_string(), val);
                } else {
                    out.bools.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name}: expected integer, got `{v}`")),
        }
    }

    /// Like [`Args::get_usize`] but enforces a lower bound — scheduler
    /// knobs such as `--max-batch` are meaningless at 0.
    pub fn get_usize_at_least(&self, name: &str, default: usize, min: usize) -> Result<usize> {
        let v = self.get_usize(name, default)?;
        if v < min {
            bail!("--{name}: must be at least {min}, got {v}");
        }
        Ok(v)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name}: expected integer, got `{v}`")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name}: expected float, got `{v}`")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }

    /// True only for value-less boolean switches.
    pub fn switch(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    /// Reject flags outside `allowed` with a usage error (`context` names
    /// the subcommand). Catches typos like `--max-batc 4`, which would
    /// otherwise be silently ignored and leave the default in effect.
    pub fn ensure_known(&self, context: &str, allowed: &[&str]) -> Result<()> {
        let present =
            self.flags.keys().map(|s| s.as_str()).chain(self.bools.iter().map(|s| s.as_str()));
        for name in present {
            if !allowed.contains(&name) {
                bail!("{context}: unknown flag `--{name}` (known: {})", allowed.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["pipeline", "--model", "small", "--bits=2", "--verbose"]);
        assert_eq!(a.positional, vec!["pipeline"]);
        assert_eq!(a.get("model"), Some("small"));
        assert_eq!(a.get("bits"), Some("2"));
        assert!(a.switch("verbose"));
        assert!(!a.switch("model"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--steps", "10", "--lr", "0.5"]);
        assert_eq!(a.get_usize("steps", 1).unwrap(), 10);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!((a.get_f64("lr", 0.0).unwrap() - 0.5).abs() < 1e-12);
        assert!(a.get_usize("lr", 0).is_err());
    }

    #[test]
    fn boolean_flag_before_flagged_value() {
        let a = parse(&["--check", "--model", "tiny"]);
        assert!(a.switch("check") || a.get("check") == Some("--model"));
        // `--check` is followed by another flag, so it's a switch:
        assert!(a.switch("check"));
        assert_eq!(a.get("model"), Some("tiny"));
    }

    #[test]
    fn bounded_getter() {
        let a = parse(&["--max-batch", "4"]);
        assert_eq!(a.get_usize_at_least("max-batch", 8, 1).unwrap(), 4);
        assert_eq!(a.get_usize_at_least("max-queued", 8, 1).unwrap(), 8);
        let z = parse(&["--max-batch", "0"]);
        assert!(z.get_usize_at_least("max-batch", 8, 1).is_err());
    }

    #[test]
    fn trailing_boolean() {
        let a = parse(&["--fast"]);
        assert!(a.switch("fast"));
    }

    #[test]
    fn explicit_empty_value_is_kept() {
        // `--http=` must not be dropped or demoted to a switch: the value
        // is present and empty, so typed getters error instead of silently
        // falling back to their default.
        let a = parse(&["--http=", "--steps=7"]);
        assert_eq!(a.get("http"), Some(""));
        assert!(a.has("http"));
        assert!(!a.switch("http"));
        assert!(a.get_usize("http", 3).is_err(), "empty value must not parse as default");
        assert_eq!(a.get_usize("steps", 1).unwrap(), 7);
    }

    #[test]
    fn eq_form_carries_values_that_start_with_dashes() {
        // `--http --bad` parses `--http` as a switch (next token is a
        // flag); the `=` form is the escape hatch for such values.
        let a = parse(&["--http=--bad", "--addr", ":8080"]);
        assert_eq!(a.get("http"), Some("--bad"));
        assert_eq!(a.get("addr"), Some(":8080"), "plain values never need the = form");
        let b = parse(&["--http", "--bad"]);
        assert!(b.switch("http"));
        assert!(b.switch("bad"));
    }

    #[test]
    fn empty_flag_name_is_rejected() {
        assert!(Args::parse(["--=x".to_string()]).is_err());
        assert!(Args::parse(["--".to_string()]).is_err());
    }

    #[test]
    fn ensure_known_rejects_typos() {
        let a = parse(&["serve", "--model", "tiny", "--stream"]);
        assert!(a.ensure_known("gq serve", &["model", "stream", "http"]).is_ok());
        let err = a.ensure_known("gq serve", &["model"]).unwrap_err().to_string();
        assert!(err.contains("unknown flag `--stream`"), "{err}");
        assert!(err.contains("gq serve"), "{err}");
    }
}
