//! Model-family presets. These mirror `python/compile/config.py` exactly;
//! the artifact manifest is cross-checked against them at load time
//! (`runtime::manifest`), so a drift between the two fails fast.

/// One named parameter tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub rows: usize,
    pub cols: usize, // 1 for vectors (norm scales)
}

/// One quantizable linear layer (7 per transformer block, Llama layout).
#[derive(Debug, Clone, PartialEq)]
pub struct LinearSpec {
    pub name: String,
    pub d_in: usize,
    pub d_out: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub rope_theta: f32,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchConfig {
    pub batch: usize,
    pub seq: usize,
}

impl BatchConfig {
    pub fn tokens(&self) -> usize {
        self.batch * self.seq
    }
}

pub const PRESET_NAMES: [&str; 3] = ["tiny", "small", "base"];

/// Look up a preset by name (panics on unknown name — callers validate).
pub fn preset(name: &str) -> (ModelConfig, BatchConfig) {
    match name {
        "tiny" => (
            ModelConfig {
                name: "tiny".into(),
                vocab: 512,
                d_model: 128,
                n_layers: 2,
                n_heads: 4,
                d_ff: 256,
                rope_theta: 10000.0,
            },
            BatchConfig { batch: 2, seq: 64 },
        ),
        "small" => (
            ModelConfig {
                name: "small".into(),
                vocab: 2048,
                d_model: 256,
                n_layers: 4,
                n_heads: 8,
                d_ff: 512,
                rope_theta: 10000.0,
            },
            BatchConfig { batch: 4, seq: 128 },
        ),
        "base" => (
            ModelConfig {
                name: "base".into(),
                vocab: 4096,
                d_model: 512,
                n_layers: 6,
                n_heads: 8,
                d_ff: 1024,
                rope_theta: 10000.0,
            },
            BatchConfig { batch: 2, seq: 128 },
        ),
        other => panic!("unknown model preset `{other}` (expected one of {PRESET_NAMES:?})"),
    }
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Canonical flat parameter order — must match python param_specs().
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        let (d, ff, v) = (self.d_model, self.d_ff, self.vocab);
        let mut out = vec![ParamSpec { name: "tok_emb".into(), rows: v, cols: d }];
        for l in 0..self.n_layers {
            let p = format!("layers.{l}.");
            let mut push = |suffix: &str, rows: usize, cols: usize| {
                out.push(ParamSpec { name: format!("{p}{suffix}"), rows, cols })
            };
            push("attn_norm", d, 1);
            push("wq", d, d);
            push("wk", d, d);
            push("wv", d, d);
            push("wo", d, d);
            push("mlp_norm", d, 1);
            push("wgate", d, ff);
            push("wup", d, ff);
            push("wdown", ff, d);
        }
        out.push(ParamSpec { name: "final_norm".into(), rows: d, cols: 1 });
        out.push(ParamSpec { name: "head".into(), rows: d, cols: v });
        out
    }

    /// The quantizable linears, flat order — must match python linear_specs().
    pub fn linear_specs(&self) -> Vec<LinearSpec> {
        let (d, ff) = (self.d_model, self.d_ff);
        let mut out = Vec::new();
        for l in 0..self.n_layers {
            let p = format!("layers.{l}.");
            let mut push = |suffix: &str, d_in: usize, d_out: usize| {
                out.push(LinearSpec { name: format!("{p}{suffix}"), d_in, d_out })
            };
            push("wq", d, d);
            push("wk", d, d);
            push("wv", d, d);
            push("wo", d, d);
            push("wgate", d, ff);
            push("wup", d, ff);
            push("wdown", ff, d);
        }
        out
    }

    pub fn n_params(&self) -> usize {
        self.param_specs().iter().map(|p| p.rows * p.cols).sum()
    }

    /// Total quantizable weight count (the denominator for avg-bits math).
    pub fn n_linear_params(&self) -> usize {
        self.linear_specs().iter().map(|l| l.d_in * l.d_out).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in PRESET_NAMES {
            let (m, b) = preset(name);
            assert_eq!(m.name, name);
            assert!(b.tokens() > 0);
            assert_eq!(m.d_model % m.n_heads, 0);
        }
    }

    #[test]
    #[should_panic(expected = "unknown model preset")]
    fn unknown_preset_panics() {
        preset("llama-2-7b");
    }

    #[test]
    fn param_specs_match_python_counts() {
        let (m, _) = preset("tiny");
        // 1 (emb) + 9 per layer * 2 + 2 (final_norm, head)
        assert_eq!(m.param_specs().len(), 1 + 9 * 2 + 2);
        assert_eq!(m.linear_specs().len(), 7 * 2);
    }

    #[test]
    fn small_param_count_is_llama_like() {
        let (m, _) = preset("small");
        let n = m.n_params();
        // ~3.7M for the small preset (see DESIGN.md §2).
        assert!((3_000_000..8_000_000).contains(&n), "{n}");
        assert!(m.n_linear_params() < n);
    }

    #[test]
    fn linear_specs_shapes() {
        let (m, _) = preset("tiny");
        let ls = m.linear_specs();
        assert_eq!(ls[0].name, "layers.0.wq");
        assert_eq!((ls[0].d_in, ls[0].d_out), (128, 128));
        let down = ls.iter().find(|l| l.name == "layers.1.wdown").unwrap();
        assert_eq!((down.d_in, down.d_out), (256, 128));
    }
}
