//! Minimal TOML-subset parser (sections, string/int/float/bool scalars,
//! `#` comments). Enough to drive deployment configs without serde.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// Parsed document: (section, key) -> value. Keys outside any section live
/// under the empty section "".
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    values: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let Some(name) = name.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected `key = value`", lineno + 1);
            };
            let key = line[..eq].trim().to_string();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            doc.values.insert((section.clone(), key), val);
        }
        Ok(doc)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<TomlDoc> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(TomlValue::Int(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(TomlValue::Float(v)) => Some(*v),
            Some(TomlValue::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(TomlValue::Bool(v)) => Some(*v),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str) -> Result<TomlValue, String> {
    if raw.is_empty() {
        return Err("empty value".into());
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            return Err("unterminated string".into());
        };
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(v) = raw.parse::<i64>() {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = raw.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    Err(format!("cannot parse value `{raw}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            "top = 1\n[pipeline]\nmodel = \"small\" # comment\nsteps = 200\nlr = 1e-3\nfast = true\n",
        )
        .unwrap();
        assert_eq!(doc.get_int("", "top"), Some(1));
        assert_eq!(doc.get_str("pipeline", "model"), Some("small"));
        assert_eq!(doc.get_int("pipeline", "steps"), Some(200));
        assert!((doc.get_float("pipeline", "lr").unwrap() - 1e-3).abs() < 1e-12);
        assert_eq!(doc.get_bool("pipeline", "fast"), Some(true));
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = TomlDoc::parse("x = 2").unwrap();
        assert_eq!(doc.get_float("", "x"), Some(2.0));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.get_str("", "s"), Some("a#b"));
    }

    #[test]
    fn errors_are_reported_with_lines() {
        let err = TomlDoc::parse("ok = 1\nbroken").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("x = @@\n").is_err());
    }

    #[test]
    fn missing_keys_are_none() {
        let doc = TomlDoc::parse("").unwrap();
        assert!(doc.get("a", "b").is_none());
        assert!(doc.get_str("", "x").is_none());
    }
}
