//! Configuration system: model presets (mirroring python/compile/config.py),
//! quantization + pipeline configs, and a minimal TOML-subset parser so
//! deployments can be driven from files without serde.

pub mod presets;
pub mod quant_cfg;
pub mod toml;

pub use presets::{preset, BatchConfig, LinearSpec, ModelConfig, ParamSpec, PRESET_NAMES};
pub use quant_cfg::{
    KvDtype, PipelineConfig, QuantConfig, QuantMethod, RestartPolicy, ServeConfig, TrellisVariant,
};
pub use toml::TomlDoc;
