//! Quantization + pipeline configuration.

use super::toml::TomlDoc;
use anyhow::{bail, Result};

/// Which quantization algorithm to run (the paper's methods + baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantMethod {
    /// Round-to-nearest uniform scalar (sanity baseline).
    Rtn,
    /// GPTQ (Frantar et al., 2023) with a uniform grid.
    Gptq,
    /// SqueezeLLM (Kim et al., 2024): diag-Fisher weighted k-means.
    SqueezeLlm,
    /// GPTVQ 1D (van Baalen et al., 2024): GD codebook + GPTQ assignments.
    Gptvq1d,
    /// GPTVQ 2D vector variant.
    Gptvq2d,
    /// LNQ (this paper, Algorithm 2).
    Lnq,
    /// QTIP-style trellis vector quantization.
    Trellis,
}

impl QuantMethod {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "rtn" => Self::Rtn,
            "gptq" => Self::Gptq,
            "squeezellm" => Self::SqueezeLlm,
            "gptvq1d" => Self::Gptvq1d,
            "gptvq2d" => Self::Gptvq2d,
            "lnq" => Self::Lnq,
            "trellis" | "qtip" => Self::Trellis,
            other => bail!("unknown quant method `{other}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Rtn => "rtn",
            Self::Gptq => "gptq",
            Self::SqueezeLlm => "squeezellm",
            Self::Gptvq1d => "gptvq1d",
            Self::Gptvq2d => "gptvq2d",
            Self::Lnq => "lnq",
            Self::Trellis => "trellis",
        }
    }
}

/// Full quantization configuration for one run.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    pub method: QuantMethod,
    /// Target bit-width b (codebook size m = 2^b for scalar LUT methods).
    pub bits: u32,
    /// GuidedQuant: number of saliency groups g; 0 disables GuidedQuant
    /// (plain layer-wise Hessian H = X^T X is used instead).
    pub groups: usize,
    /// LNQ alternating iterations T (paper: 2 for 7B/13B, 1 for 70B).
    pub lnq_iters: usize,
    /// CD cycles K (paper: 4).
    pub cd_cycles: usize,
    /// Lazy-batch block size b for CD/GPTQ (paper: 128; scaled down here).
    pub cd_block: usize,
    /// Dense-and-sparse: fraction of weights kept fp (paper: 0.45% = 0.0045).
    pub sparse_frac: f32,
    /// Vector quantization dimension (GPTVQ 2D / trellis).
    pub vq_dim: usize,
    /// Trellis variant: "1mad" | "3inst" | "hyb".
    pub trellis_variant: TrellisVariant,
    pub seed: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrellisVariant {
    OneMad,
    ThreeInst,
    Hyb,
}

impl TrellisVariant {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "1mad" => Self::OneMad,
            "3inst" => Self::ThreeInst,
            "hyb" => Self::Hyb,
            other => bail!("unknown trellis variant `{other}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::OneMad => "1mad",
            Self::ThreeInst => "3inst",
            Self::Hyb => "hyb",
        }
    }
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            method: QuantMethod::Lnq,
            bits: 4,
            groups: 4,
            lnq_iters: 2,
            cd_cycles: 4,
            cd_block: 32,
            sparse_frac: 0.0,
            vq_dim: 2,
            trellis_variant: TrellisVariant::Hyb,
            seed: 0,
        }
    }
}

impl QuantConfig {
    pub fn with(method: QuantMethod, bits: u32, groups: usize) -> Self {
        QuantConfig { method, bits, groups, ..Default::default() }
    }

    /// Codebook size for scalar LUT methods.
    pub fn codebook_size(&self) -> usize {
        1usize << self.bits
    }

    pub fn from_toml(doc: &TomlDoc, section: &str) -> Result<Self> {
        let mut c = QuantConfig::default();
        if let Some(v) = doc.get_str(section, "method") {
            c.method = QuantMethod::parse(v)?;
        }
        if let Some(v) = doc.get_int(section, "bits") {
            c.bits = v as u32;
        }
        if let Some(v) = doc.get_int(section, "groups") {
            c.groups = v as usize;
        }
        if let Some(v) = doc.get_int(section, "lnq_iters") {
            c.lnq_iters = v as usize;
        }
        if let Some(v) = doc.get_int(section, "cd_cycles") {
            c.cd_cycles = v as usize;
        }
        if let Some(v) = doc.get_float(section, "sparse_frac") {
            c.sparse_frac = v as f32;
        }
        if let Some(v) = doc.get_int(section, "vq_dim") {
            c.vq_dim = v as usize;
        }
        if let Some(v) = doc.get_str(section, "trellis_variant") {
            c.trellis_variant = TrellisVariant::parse(v)?;
        }
        if let Some(v) = doc.get_int(section, "seed") {
            c.seed = v as u64;
        }
        Ok(c)
    }
}

/// Storage dtype of the paged KV cache (`model::attention`).
///
/// `F32` is the exact default: decode output is bit-identical to the
/// reference path at any SIMD/tile/thread setting. `F16` halves KV bytes
/// per token — the dominant stream of small-batch decode — by storing
/// pages as IEEE binary16 (`util::half`), widening exactly on read; only
/// the store rounds (to nearest even), so outputs are ULP-close to f32,
/// not bit-equal, which is why it is an explicit opt-in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    #[default]
    F32,
    F16,
}

impl KvDtype {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" | "fp32" => Self::F32,
            "f16" | "fp16" | "half" => Self::F16,
            other => bail!("unknown kv dtype `{other}` (expected f32 or f16)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::F16 => "f16",
        }
    }

    /// Bytes per stored KV element.
    pub fn bytes(&self) -> usize {
        match self {
            Self::F32 => 4,
            Self::F16 => 2,
        }
    }
}

/// What the engine supervisor does with in-flight requests when an
/// unattributable fault forces an engine restart (`serve::SupervisedEngine`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RestartPolicy {
    /// Fail every in-flight request with a 500 and restart empty. The
    /// default: honest (no silent re-execution) and bounded-latency.
    #[default]
    FailFast,
    /// Resubmit in-flight requests to the fresh engine under their
    /// original ids and deadlines. Greedy decode is deterministic, so
    /// replayed tokens are bit-identical and the supervisor suppresses
    /// the ones already streamed.
    Requeue,
}

impl RestartPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fail-fast" | "failfast" => Self::FailFast,
            "requeue" => Self::Requeue,
            other => bail!("unknown restart policy `{other}` (expected fail-fast or requeue)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::FailFast => "fail-fast",
            Self::Requeue => "requeue",
        }
    }
}

/// Serving/scheduler knobs for the continuous-batching engine
/// (`gq serve`, `serve::Scheduler`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum sequences decoding concurrently (continuous-batch width);
    /// finished sequences are evicted mid-flight and queued requests
    /// spliced in at the next step.
    pub max_batch: usize,
    /// Admission control: maximum requests waiting in the queue before
    /// `submit` errors (back-pressure to the caller).
    pub max_queued: usize,
    /// Engine worker threads (scalar-prefill fan-out; the batched kernels
    /// size themselves from the shared pool). 0 = auto: follow
    /// `tensor::ops::num_threads()` (and its `GQ_THREADS` override).
    pub workers: usize,
    /// Use the per-lane scalar prefill reference path instead of chunked
    /// batched prefill — kept as the bit-identity regression baseline and
    /// for benchmarking the chunked-prefill win.
    pub scalar_prefill: bool,
    /// Bind address for the HTTP front-end (`serve::http`), e.g.
    /// `127.0.0.1:8080` (port 0 picks a free port). `None` keeps `gq serve`
    /// in its stdout benchmark mode; `gq serve --http ADDR` overrides.
    pub http_addr: Option<String>,
    /// KV cache storage dtype (`kv_dtype = "f16"` in TOML,
    /// `gq serve --kv-dtype f16`). Defaults to exact f32.
    pub kv_dtype: KvDtype,
    /// Default wall-clock budget per request (submit → completion), in
    /// milliseconds; expired lanes are evicted with partial output and
    /// `finish_reason = "timeout"`. 0 disables. Per-request `timeout_ms`
    /// in the HTTP body overrides this.
    pub request_timeout_ms: u64,
    /// Maximum time a request may wait in the admission queue before it
    /// expires un-decoded, in milliseconds. 0 disables.
    pub queue_timeout_ms: u64,
    /// What happens to in-flight requests when a fault forces an engine
    /// restart (`restart_policy = "fail-fast" | "requeue"` in TOML).
    pub restart_policy: RestartPolicy,
    /// Engine restarts tolerated before the supervisor declares the
    /// engine dead (`/healthz` flips to 503 and the server drains).
    pub max_engine_restarts: usize,
    /// Hard cap on KV cache memory, in bytes (`kv_budget_mb` in TOML,
    /// `gq serve --kv-budget-mb N`). 0 disables governance. When set,
    /// admission estimates each request's worst-case page cost from
    /// prompt length + `max_tokens` and refuses to start requests that
    /// would push live KV past the high watermark; the scheduler
    /// brownouts (clamps `max_tokens`) above the low watermark and
    /// preempts the youngest lane above the high watermark. Watermarks
    /// are fixed fractions of the budget (`serve::scheduler::KV_LOW_WATERMARK`
    /// / `KV_HIGH_WATERMARK`).
    pub kv_budget_bytes: usize,
    /// Copy-on-write prefix-sharing KV cache (`prefix_cache = false` in
    /// TOML, `gq serve --prefix-cache off`). Defaults to on. When enabled
    /// the scheduler keeps a radix index of finished lanes' page-aligned
    /// prompt prefixes; new requests that share a cached prefix map those
    /// pages read-only and skip prefill over the cached positions. Greedy
    /// outputs are bit-identical either way.
    pub prefix_cache: bool,
    /// Default decode precision (planes read per weight) for requests
    /// that don't ask for one (`precision = 3` in TOML, `gq serve
    /// --precision 3`). Only meaningful with `--format anyprec`, whose
    /// bit-plane artifact serves any prefix of its stored planes; 0 (the
    /// default) means "the format's native full precision".
    pub default_precision: u8,
    /// Load-shed floor precision (`precision_floor` in TOML, `gq serve
    /// --precision-floor 2`). When set (non-zero) and the KV budget is
    /// above the brownout low watermark, new admissions are downshifted
    /// to this precision instead of having their `max_tokens` browned
    /// out — a milder governance rung that trades decode quality for
    /// full-length, non-degraded answers. 0 (the default) disables the
    /// rung.
    pub precision_floor: u8,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_queued: 256,
            workers: 0,
            scalar_prefill: false,
            http_addr: None,
            kv_dtype: KvDtype::F32,
            request_timeout_ms: 0,
            queue_timeout_ms: 0,
            restart_policy: RestartPolicy::FailFast,
            max_engine_restarts: 3,
            kv_budget_bytes: 0,
            prefix_cache: true,
            default_precision: 0,
            precision_floor: 0,
        }
    }
}

impl ServeConfig {
    /// Effective worker count: `workers`, or the shared-pool width when 0.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            crate::tensor::ops::num_threads()
        } else {
            self.workers
        }
    }

    pub fn from_toml(doc: &TomlDoc, section: &str) -> Result<Self> {
        let mut c = ServeConfig::default();
        if let Some(v) = doc.get_int(section, "max_batch") {
            c.max_batch = v as usize;
        }
        if let Some(v) = doc.get_int(section, "max_queued") {
            c.max_queued = v as usize;
        }
        if let Some(v) = doc.get_int(section, "workers") {
            c.workers = v as usize; // 0 = auto
        }
        if let Some(v) = doc.get_bool(section, "scalar_prefill") {
            c.scalar_prefill = v;
        }
        if let Some(v) = doc.get_str(section, "http") {
            c.http_addr = Some(v.to_string());
        }
        if let Some(v) = doc.get_str(section, "kv_dtype") {
            c.kv_dtype = KvDtype::parse(v)?;
        }
        if let Some(v) = doc.get_int(section, "request_timeout_ms") {
            c.request_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_int(section, "queue_timeout_ms") {
            c.queue_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_str(section, "restart_policy") {
            c.restart_policy = RestartPolicy::parse(v)?;
        }
        if let Some(v) = doc.get_int(section, "max_engine_restarts") {
            c.max_engine_restarts = v as usize;
        }
        if let Some(v) = doc.get_int(section, "kv_budget_mb") {
            if v < 0 {
                bail!("serve.kv_budget_mb must be non-negative");
            }
            c.kv_budget_bytes = (v as usize) * 1024 * 1024;
        }
        if let Some(v) = doc.get_bool(section, "prefix_cache") {
            c.prefix_cache = v;
        }
        if let Some(v) = doc.get_int(section, "precision") {
            if !(0..=16).contains(&v) {
                bail!("serve.precision must be in 0..=16 (0 = native)");
            }
            c.default_precision = v as u8;
        }
        if let Some(v) = doc.get_int(section, "precision_floor") {
            if !(0..=16).contains(&v) {
                bail!("serve.precision_floor must be in 0..=16 (0 = off)");
            }
            c.precision_floor = v as u8;
        }
        if c.default_precision != 0
            && c.precision_floor != 0
            && c.precision_floor > c.default_precision
        {
            bail!("serve.precision_floor must not exceed serve.precision");
        }
        if c.max_batch == 0 {
            bail!("serve.max_batch must be at least 1");
        }
        if c.max_queued == 0 {
            bail!("serve.max_queued must be at least 1");
        }
        Ok(c)
    }
}

/// End-to-end pipeline configuration (`gq pipeline`).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub model: String,
    pub artifacts_dir: String,
    pub out_dir: String,
    /// Training steps driven through the train_step artifact.
    pub train_steps: usize,
    /// Calibration batches for Hessian/saliency accumulation.
    pub calib_batches: usize,
    /// Evaluation batches for perplexity.
    pub eval_batches: usize,
    /// Worker threads for the (layer, group) quantization job queue.
    /// Defaults to `tensor::ops::num_threads()` — the shared-pool width,
    /// including the `GQ_THREADS` env override.
    pub workers: usize,
    pub quant: QuantConfig,
    pub serve: ServeConfig,
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            model: "small".into(),
            artifacts_dir: "artifacts".into(),
            out_dir: "target/gq".into(),
            train_steps: 200,
            calib_batches: 8,
            eval_batches: 16,
            workers: crate::tensor::ops::num_threads(),
            quant: QuantConfig::default(),
            serve: ServeConfig::default(),
            seed: 0,
        }
    }
}

impl PipelineConfig {
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut c = PipelineConfig::default();
        let s = "pipeline";
        if let Some(v) = doc.get_str(s, "model") {
            c.model = v.to_string();
        }
        if let Some(v) = doc.get_str(s, "artifacts_dir") {
            c.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get_str(s, "out_dir") {
            c.out_dir = v.to_string();
        }
        if let Some(v) = doc.get_int(s, "train_steps") {
            c.train_steps = v as usize;
        }
        if let Some(v) = doc.get_int(s, "calib_batches") {
            c.calib_batches = v as usize;
        }
        if let Some(v) = doc.get_int(s, "eval_batches") {
            c.eval_batches = v as usize;
        }
        if let Some(v) = doc.get_int(s, "workers") {
            c.workers = v as usize;
        }
        if let Some(v) = doc.get_int(s, "seed") {
            c.seed = v as u64;
        }
        c.quant = QuantConfig::from_toml(doc, "quant")?;
        c.serve = ServeConfig::from_toml(doc, "serve")?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_round_trip() {
        for m in [
            QuantMethod::Rtn,
            QuantMethod::Gptq,
            QuantMethod::SqueezeLlm,
            QuantMethod::Gptvq1d,
            QuantMethod::Gptvq2d,
            QuantMethod::Lnq,
            QuantMethod::Trellis,
        ] {
            assert_eq!(QuantMethod::parse(m.name()).unwrap(), m);
        }
        assert!(QuantMethod::parse("awq").is_err());
    }

    #[test]
    fn codebook_size_follows_bits() {
        let c = QuantConfig::with(QuantMethod::Lnq, 3, 4);
        assert_eq!(c.codebook_size(), 8);
    }

    #[test]
    fn from_toml_overrides_defaults() {
        let doc = TomlDoc::parse(
            "[pipeline]\nmodel = \"tiny\"\ntrain_steps = 7\n[quant]\nmethod = \"gptq\"\nbits = 2\nsparse_frac = 0.0045\n[serve]\nmax_batch = 16\nmax_queued = 99\n",
        )
        .unwrap();
        let c = PipelineConfig::from_toml(&doc).unwrap();
        assert_eq!(c.model, "tiny");
        assert_eq!(c.train_steps, 7);
        assert_eq!(c.quant.method, QuantMethod::Gptq);
        assert_eq!(c.quant.bits, 2);
        assert!((c.quant.sparse_frac - 0.0045).abs() < 1e-9);
        assert_eq!(c.serve.max_batch, 16);
        assert_eq!(c.serve.max_queued, 99);
    }

    #[test]
    fn serve_config_rejects_zero_knobs() {
        let doc = TomlDoc::parse("[serve]\nmax_batch = 0\n").unwrap();
        assert!(ServeConfig::from_toml(&doc, "serve").is_err());
        let doc = TomlDoc::parse("[serve]\nmax_queued = 0\n").unwrap();
        assert!(ServeConfig::from_toml(&doc, "serve").is_err());
        let c = ServeConfig::default();
        assert!(c.max_batch >= 1 && c.max_queued >= 1);
    }

    #[test]
    fn serve_workers_default_to_pool_width() {
        let c = ServeConfig::default();
        assert_eq!(c.workers, 0, "0 = auto");
        assert_eq!(c.resolved_workers(), crate::tensor::ops::num_threads());
        assert!(!c.scalar_prefill);
        let doc =
            TomlDoc::parse("[serve]\nworkers = 3\nscalar_prefill = true\n").unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.resolved_workers(), 3);
        assert!(c.scalar_prefill);
    }

    #[test]
    fn serve_http_addr_from_toml() {
        let c = ServeConfig::default();
        assert_eq!(c.http_addr, None, "stdout mode by default");
        let doc = TomlDoc::parse("[serve]\nhttp = \"127.0.0.1:8080\"\n").unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert_eq!(c.http_addr.as_deref(), Some("127.0.0.1:8080"));
    }

    #[test]
    fn kv_dtype_parses_and_defaults_to_f32() {
        let c = ServeConfig::default();
        assert_eq!(c.kv_dtype, KvDtype::F32, "f16 KV must stay opt-in");
        assert_eq!(KvDtype::parse("f16").unwrap(), KvDtype::F16);
        assert_eq!(KvDtype::parse("fp16").unwrap(), KvDtype::F16);
        assert_eq!(KvDtype::parse("f32").unwrap(), KvDtype::F32);
        assert!(KvDtype::parse("bf16").is_err());
        assert_eq!(KvDtype::F16.bytes(), 2);
        assert_eq!(KvDtype::F32.bytes(), 4);
        assert_eq!(KvDtype::F16.name(), "f16");
        let doc = TomlDoc::parse("[serve]\nkv_dtype = \"f16\"\n").unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert_eq!(c.kv_dtype, KvDtype::F16);
        let doc = TomlDoc::parse("[serve]\nkv_dtype = \"int8\"\n").unwrap();
        assert!(ServeConfig::from_toml(&doc, "serve").is_err());
    }

    #[test]
    fn restart_policy_and_timeout_knobs_from_toml() {
        let c = ServeConfig::default();
        assert_eq!(c.request_timeout_ms, 0, "no deadline by default");
        assert_eq!(c.queue_timeout_ms, 0);
        assert_eq!(c.restart_policy, RestartPolicy::FailFast);
        assert_eq!(c.max_engine_restarts, 3);
        assert_eq!(RestartPolicy::parse("fail-fast").unwrap(), RestartPolicy::FailFast);
        assert_eq!(RestartPolicy::parse("requeue").unwrap(), RestartPolicy::Requeue);
        assert!(RestartPolicy::parse("retry").is_err());
        assert_eq!(RestartPolicy::Requeue.name(), "requeue");
        let doc = TomlDoc::parse(
            "[serve]\nrequest_timeout_ms = 5000\nqueue_timeout_ms = 1000\nrestart_policy = \"requeue\"\nmax_engine_restarts = 1\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert_eq!(c.request_timeout_ms, 5000);
        assert_eq!(c.queue_timeout_ms, 1000);
        assert_eq!(c.restart_policy, RestartPolicy::Requeue);
        assert_eq!(c.max_engine_restarts, 1);
        let doc = TomlDoc::parse("[serve]\nrestart_policy = \"retry\"\n").unwrap();
        assert!(ServeConfig::from_toml(&doc, "serve").is_err());
    }

    #[test]
    fn kv_budget_from_toml_in_mb_defaults_off() {
        let c = ServeConfig::default();
        assert_eq!(c.kv_budget_bytes, 0, "governance must stay opt-in");
        let doc = TomlDoc::parse("[serve]\nkv_budget_mb = 2\n").unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert_eq!(c.kv_budget_bytes, 2 * 1024 * 1024);
        let doc = TomlDoc::parse("[serve]\nkv_budget_mb = 0\n").unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert_eq!(c.kv_budget_bytes, 0);
        let doc = TomlDoc::parse("[serve]\nkv_budget_mb = -1\n").unwrap();
        assert!(ServeConfig::from_toml(&doc, "serve").is_err());
    }

    #[test]
    fn prefix_cache_defaults_on_and_toml_disables() {
        let c = ServeConfig::default();
        assert!(c.prefix_cache, "prefix sharing is free — on by default");
        let doc = TomlDoc::parse("[serve]\nprefix_cache = false\n").unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert!(!c.prefix_cache);
        let doc = TomlDoc::parse("[serve]\nprefix_cache = true\n").unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert!(c.prefix_cache);
    }

    #[test]
    fn precision_knobs_from_toml_default_native() {
        let c = ServeConfig::default();
        assert_eq!(c.default_precision, 0, "0 = the format's native precision");
        assert_eq!(c.precision_floor, 0, "downshift rung must stay opt-in");
        let doc = TomlDoc::parse("[serve]\nprecision = 4\nprecision_floor = 2\n").unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert_eq!(c.default_precision, 4);
        assert_eq!(c.precision_floor, 2);
        // Floor above the default is a misconfiguration.
        let doc = TomlDoc::parse("[serve]\nprecision = 2\nprecision_floor = 3\n").unwrap();
        assert!(ServeConfig::from_toml(&doc, "serve").is_err());
        // A floor with a native (0) default is fine: the floor only has
        // to be ≤ the artifact's bits, checked at serve start.
        let doc = TomlDoc::parse("[serve]\nprecision_floor = 2\n").unwrap();
        let c = ServeConfig::from_toml(&doc, "serve").unwrap();
        assert_eq!(c.default_precision, 0);
        assert_eq!(c.precision_floor, 2);
        let doc = TomlDoc::parse("[serve]\nprecision = 17\n").unwrap();
        assert!(ServeConfig::from_toml(&doc, "serve").is_err());
        let doc = TomlDoc::parse("[serve]\nprecision = -1\n").unwrap();
        assert!(ServeConfig::from_toml(&doc, "serve").is_err());
    }

    #[test]
    fn pipeline_workers_default_follows_num_threads() {
        let c = PipelineConfig::default();
        assert_eq!(c.workers, crate::tensor::ops::num_threads());
    }
}
