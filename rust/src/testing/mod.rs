//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random seeds;
//! on failure it reports the failing seed so the case can be replayed as a
//! deterministic regression (`replay(seed, f)`). Used by the quantization
//! solvers to pin the paper's invariants (e.g. LNQ's Prop 4.1 descent
//! guarantee) across randomized problem instances.

use crate::util::Rng;

/// Run `f` over `cases` independently-seeded RNGs. Panics with the failing
/// seed if `f` panics or returns `Err`.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = env_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15) | 1);
        let mut rng = Rng::new(seed);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!("property `{name}` failed (replay seed {seed:#x}): {msg}"),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<panic>".into());
                panic!("property `{name}` panicked (replay seed {seed:#x}): {msg}");
            }
        }
    }
}

/// Replay one failing case from its reported seed.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    f(&mut rng).expect("replayed case failed");
}

fn env_seed() -> u64 {
    std::env::var("GQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Assert |a - b| <= atol + rtol*|b| elementwise, with context on failure.
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("at {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Convenience: fail with a formatted message if `cond` is false.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_when_property_holds() {
        check("sum-commutes", 50, |rng| {
            let a = rng.f64();
            let b = rng.f64();
            ensure(a + b == b + a, "addition must commute")
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn check_reports_seed_on_failure() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-7], 1e-5, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[2.0], 1e-5, 1e-6).is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0], 0.0, 0.0).is_err());
    }
}
