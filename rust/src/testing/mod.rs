//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random seeds;
//! on failure it reports the failing seed so the case can be replayed as a
//! deterministic regression (`replay(seed, f)`). Used by the quantization
//! solvers to pin the paper's invariants (e.g. LNQ's Prop 4.1 descent
//! guarantee) across randomized problem instances.

use crate::util::Rng;

pub mod alloc_count {
    //! Heap-allocation probe for the zero-allocation steady-state tests.
    //!
    //! [`CountingAllocator`] wraps the system allocator and counts
    //! allocation events (alloc / alloc_zeroed / realloc) made by the
    //! *current thread* while a [`count_allocs`] probe is active. Counting
    //! is thread-local so concurrently running tests (and pool workers) do
    //! not pollute each other's probes; the flip side is that work fanned
    //! out to pool threads is not attributed to the probing thread, so
    //! probes should measure code paths that stay below the parallelism
    //! thresholds. The crate's test harness installs this as the global
    //! allocator (`#[cfg(test)]` in `lib.rs`); outside the test harness
    //! [`count_allocs`] simply reports 0.

    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        // `const` + no-Drop payloads: plain TLS slots, no lazy-init
        // registration — safe to touch from inside the allocator.
        static ENABLED: Cell<bool> = const { Cell::new(false) };
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    pub struct CountingAllocator;

    #[inline]
    fn note() {
        ENABLED.with(|e| {
            if e.get() {
                ALLOCS.with(|c| c.set(c.get() + 1));
            }
        });
    }

    unsafe impl GlobalAlloc for CountingAllocator {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            note();
            System.alloc(layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            note();
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            note();
            System.realloc(ptr, layout, new_size)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }

    /// Run `f`, returning its value and the number of heap allocations the
    /// current thread made while it ran.
    pub fn count_allocs<T>(f: impl FnOnce() -> T) -> (T, u64) {
        ALLOCS.with(|c| c.set(0));
        ENABLED.with(|e| e.set(true));
        let out = f();
        ENABLED.with(|e| e.set(false));
        (out, ALLOCS.with(|c| c.get()))
    }
}

/// Run `f` over `cases` independently-seeded RNGs. Panics with the failing
/// seed if `f` panics or returns `Err`.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = env_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15) | 1);
        let mut rng = Rng::new(seed);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!("property `{name}` failed (replay seed {seed:#x}): {msg}"),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<panic>".into());
                panic!("property `{name}` panicked (replay seed {seed:#x}): {msg}");
            }
        }
    }
}

/// Replay one failing case from its reported seed.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    f(&mut rng).expect("replayed case failed");
}

fn env_seed() -> u64 {
    std::env::var("GQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Assert |a - b| <= atol + rtol*|b| elementwise, with context on failure.
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("at {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Map an f32 onto the integer line so that adjacent representable floats
/// differ by exactly 1 (the standard ordered-bits trick; ±0 map to the
/// same point, so they count as equal).
fn ordered(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 == 0 {
        b as i64
    } else {
        -((b & 0x7fff_ffff) as i64)
    }
}

/// ULP distance between two f32s (0 = bit-identical or ±0 pair). The
/// distance crosses zero correctly: `ulp_distance(-ε, +ε)` is 2, not huge.
pub fn ulp_distance(a: f32, b: f32) -> u64 {
    (ordered(a) - ordered(b)).unsigned_abs()
}

/// Assert elementwise ULP closeness with an absolute floor — the contract
/// language of the f16-storage tests, where errors are relative by nature
/// (an f16 rounding step is ~2^-11 relative, i.e. ~2^13 f32 ULPs). A pure
/// ULP bound explodes when an output element happens to land near zero
/// (its ULPs shrink with it while the propagated error does not), so an
/// element also passes when `|x - y| <= atol`; pass `atol = 0.0` for a
/// strict ULP check. NaNs must match positionally; infinities must be
/// equal exactly.
pub fn assert_close_ulp(a: &[f32], b: &[f32], max_ulp: u64, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        if x.is_nan() || y.is_nan() {
            if x.is_nan() != y.is_nan() {
                return Err(format!("at {i}: NaN mismatch ({x} vs {y})"));
            }
            continue;
        }
        if (x - y).abs() <= atol {
            continue;
        }
        let d = ulp_distance(x, y);
        if d > max_ulp {
            return Err(format!("at {i}: {x} vs {y} is {d} ulps apart (max {max_ulp})"));
        }
    }
    Ok(())
}

/// Convenience: fail with a formatted message if `cond` is false.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_when_property_holds() {
        check("sum-commutes", 50, |rng| {
            let a = rng.f64();
            let b = rng.f64();
            ensure(a + b == b + a, "addition must commute")
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn check_reports_seed_on_failure() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn alloc_probe_counts_only_this_threads_allocations() {
        use super::alloc_count::count_allocs;
        let (v, n) = count_allocs(|| {
            let v: Vec<u64> = Vec::with_capacity(32);
            std::hint::black_box(v)
        });
        assert_eq!(v.capacity(), 32);
        assert!(n >= 1, "allocation not observed by the probe");
        let (x, n) = count_allocs(|| std::hint::black_box(1u32) + 1);
        assert_eq!(x, 2);
        assert_eq!(n, 0, "allocation-free closure must count zero");
    }

    #[test]
    fn ulp_distance_counts_representable_steps() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert_eq!(ulp_distance(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        // Crossing zero: smallest positive and smallest negative subnormal
        // are two steps apart (through ±0).
        let tiny = f32::from_bits(1);
        assert_eq!(ulp_distance(tiny, -tiny), 2);
        // One f16 rounding step at 1.0 is 2^-11 relative = 2^12 f32 ulps.
        assert_eq!(ulp_distance(1.0, 1.0 + 2.0f32.powi(-11)), 1 << 12);
    }

    #[test]
    fn assert_close_ulp_bounds_and_nan_rules() {
        assert!(assert_close_ulp(&[1.0, 2.0], &[1.0, 2.0], 0, 0.0).is_ok());
        let next = f32::from_bits(1.0f32.to_bits() + 3);
        assert!(assert_close_ulp(&[next], &[1.0], 3, 0.0).is_ok());
        assert!(assert_close_ulp(&[next], &[1.0], 2, 0.0).is_err());
        assert!(assert_close_ulp(&[f32::NAN], &[f32::NAN], 0, 0.0).is_ok());
        assert!(assert_close_ulp(&[f32::NAN], &[1.0], u64::MAX, 1e9).is_err());
        assert!(assert_close_ulp(&[1.0], &[1.0, 2.0], 0, 0.0).is_err());
        assert!(assert_close_ulp(&[f32::INFINITY], &[f32::INFINITY], 0, 0.0).is_ok());
        // The absolute floor rescues near-zero elements whose tiny absolute
        // error is huge in ULPs...
        assert!(assert_close_ulp(&[1e-6], &[2e-6], 8, 0.0).is_err());
        assert!(assert_close_ulp(&[1e-6], &[2e-6], 8, 1e-5).is_ok());
        // ...but does not loosen well-scaled elements.
        assert!(assert_close_ulp(&[2.0], &[1.0], 8, 1e-5).is_err());
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-7], 1e-5, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[2.0], 1e-5, 1e-6).is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0], 0.0, 0.0).is_err());
    }
}
