//! Chaos integration: deterministic fault injection (`util::fault`)
//! against a live [`HttpServer`]. Each scenario arms a process-global
//! fault site, drives real HTTP clients into it, and asserts the
//! supervision contract: the poisoned request fails with a 5xx (or an SSE
//! error event), everything else keeps streaming, KV pages return to the
//! arena, and a fault-free follow-up request is served bit-identically to
//! the in-process scheduler path.
//!
//! Global fault sites are process-wide, so every test serializes on
//! [`SERIAL`] and disarms on entry and exit (panic included) — scenarios
//! can never leak injected faults into each other.
//!
//! The overload scenarios (KV budget flood, brownout, kv-exhaust,
//! slow-read, predicted-deadline shedding) assert the PR 8 governance
//! contract: `kv_allocated_bytes` never exceeds `kv_budget_bytes`,
//! `/healthz` stays 200 under pressure, every request resolves (200,
//! degraded 200, or 429 with a computed Retry-After — never a hang), and
//! post-overload outputs are bit-identical to an unloaded engine.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use guidedquant::cfg::{preset, RestartPolicy, ServeConfig};
use guidedquant::model::{NativeModel, ParamStore};
use guidedquant::serve::{build_serving_set, generate_scheduled, HttpServer, ModelSet, ServeFormat};
use guidedquant::util::fault;
use guidedquant::util::json::Json;
use guidedquant::util::Rng;

static SERIAL: Mutex<()> = Mutex::new(());

/// Holds the serialization lock for a scenario and guarantees the global
/// fault registry is clean on both ends, even when an assertion panics
/// while a site is still armed.
struct FaultScope<'a>(#[allow(dead_code)] MutexGuard<'a, ()>);

impl Drop for FaultScope<'_> {
    fn drop(&mut self) {
        fault::disarm_all_global();
    }
}

fn scenario() -> FaultScope<'static> {
    // A previous test panicking mid-scenario poisons the mutex; the lock
    // itself is still a valid serialization token.
    let g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    fault::disarm_all_global();
    FaultScope(g)
}

fn model() -> Arc<ModelSet> {
    let (cfg, _) = preset("tiny");
    let ps = ParamStore::init(&cfg, &mut Rng::new(0));
    Arc::new(build_serving_set(&ps, None, ServeFormat::Fp32, 4).unwrap())
}

fn serve(cfg: ServeConfig) -> (Arc<ModelSet>, HttpServer) {
    let m = model();
    let server = HttpServer::bind(m.clone(), cfg, "127.0.0.1:0").unwrap();
    (m, server)
}

struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Response {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

fn request(addr: SocketAddr, raw: &str) -> Response {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        let (k, v) = t.split_once(':').unwrap();
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let chunked = headers.iter().any(|(k, v)| k == "transfer-encoding" && v.contains("chunked"));
    let body = if chunked {
        let mut out = String::new();
        loop {
            let mut sz = String::new();
            r.read_line(&mut sz).unwrap();
            let n = usize::from_str_radix(sz.trim(), 16).unwrap();
            let mut buf = vec![0u8; n + 2];
            r.read_exact(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            out.push_str(std::str::from_utf8(&buf[..n]).unwrap());
        }
        out
    } else {
        let cl = headers.iter().find(|(k, _)| k == "content-length").expect("content-length");
        let n: usize = cl.1.parse().unwrap();
        let mut buf = vec![0u8; n];
        r.read_exact(&mut buf).unwrap();
        String::from_utf8(buf).unwrap()
    };
    Response { status, headers, body }
}

fn get(addr: SocketAddr, path: &str) -> Response {
    request(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"))
}

fn post(addr: SocketAddr, path: &str, body: &str) -> Response {
    request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn completion_body(prompt: &[u32], max_tokens: usize, stream: bool) -> String {
    let toks: Vec<Json> = prompt.iter().map(|&t| Json::from(t)).collect();
    Json::object()
        .with("prompt", toks)
        .with("max_tokens", max_tokens)
        .with("stream", stream)
        .encode()
}

fn completion_body_deadline(prompt: &[u32], max_tokens: usize, timeout_ms: u64) -> String {
    let toks: Vec<Json> = prompt.iter().map(|&t| Json::from(t)).collect();
    Json::object()
        .with("prompt", toks)
        .with("max_tokens", max_tokens)
        .with("timeout_ms", timeout_ms)
        .encode()
}

/// A 429 must carry a computed, in-range Retry-After — never 0, never
/// past the 60s clamp.
fn assert_sane_retry_after(resp: &Response) {
    let ra: u64 = resp
        .header("retry-after")
        .expect("429 without Retry-After")
        .parse()
        .expect("non-numeric Retry-After");
    assert!((1..=60).contains(&ra), "Retry-After {ra} outside the 1-60s clamp");
}

fn response_tokens(body: &str) -> Vec<u32> {
    let doc = Json::parse(body).unwrap();
    let arr = doc.get("tokens").unwrap().as_arr().unwrap().to_vec();
    arr.iter().map(|t| t.as_u64().unwrap() as u32).collect()
}

fn sse_events(body: &str) -> Vec<String> {
    body.lines().filter(|l| l.starts_with("data: ")).map(|l| l[6..].to_string()).collect()
}

/// The token payloads of a streamed body, in order.
fn streamed_tokens(body: &str) -> Vec<u32> {
    sse_events(body)
        .iter()
        .filter_map(|e| Json::parse(e).ok())
        .filter_map(|ev| ev.get("token").and_then(|t| t.as_u64()).map(|t| t as u32))
        .collect()
}

fn reference_tokens(m: &NativeModel, prompt: &[u32], gen: usize) -> Vec<u32> {
    let (outs, _) =
        generate_scheduled(m, &[prompt.to_vec()], gen, 1, ServeConfig::default()).unwrap();
    outs.into_iter().next().unwrap()
}

fn wait_for_metrics(addr: SocketAddr, pred: impl Fn(&Json) -> bool, what: &str) {
    let t0 = Instant::now();
    loop {
        let m = Json::parse(&get(addr, "/metrics").body).unwrap();
        if pred(&m) {
            return;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// After a fault the server must keep serving: a fresh request returns
/// exactly the in-process scheduler tokens.
fn assert_serves_bit_identically(addr: SocketAddr, m: &NativeModel) {
    let prompt = [3u32, 17, 99, 5];
    let resp = post(addr, "/v1/completions", &completion_body(&prompt, 6, false));
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(
        response_tokens(&resp.body),
        reference_tokens(m, &prompt, 6),
        "post-fault tokens diverged from the scheduler path"
    );
}

#[test]
fn step_panic_on_a_single_lane_returns_500_and_recovers() {
    let _scope = scenario();
    let (m, server) = serve(ServeConfig::default());
    let addr = server.local_addr();

    // Third decode step panics; with one active lane the supervisor pins
    // the fault on that request — no engine restart.
    fault::arm_global(fault::STEP_PANIC, 3);
    let resp = post(addr, "/v1/completions", &completion_body(&[1, 2, 3], 8, false));
    assert_eq!(resp.status, 500, "{}", resp.body);
    assert!(Json::parse(&resp.body).unwrap().get("error").is_some());

    let h = Json::parse(&get(addr, "/healthz").body).unwrap();
    assert_eq!(h.get("engine_alive").unwrap().as_bool(), Some(true));
    assert_eq!(h.get("engine_restarts").unwrap().as_u64(), Some(0));
    wait_for_metrics(
        addr,
        |mx| {
            mx.get("failed").unwrap().as_u64() == Some(1)
                && mx.get("kv_bytes").unwrap().as_u64() == Some(0)
        },
        "failed counter + kv release",
    );
    assert_serves_bit_identically(addr, m.native_model());
    server.shutdown();
}

#[test]
fn nan_logits_poison_one_request_not_the_engine() {
    let _scope = scenario();
    let (m, server) = serve(ServeConfig::default());
    let addr = server.local_addr();

    fault::arm_global(fault::NAN_LOGITS, 2);
    let resp = post(addr, "/v1/completions", &completion_body(&[4, 4, 4], 8, false));
    assert_eq!(resp.status, 500, "a poisoned logit row must not serve garbage tokens");
    wait_for_metrics(
        addr,
        |mx| {
            mx.get("failed").unwrap().as_u64() == Some(1)
                && mx.get("kv_bytes").unwrap().as_u64() == Some(0)
        },
        "poisoned lane failure",
    );
    assert_serves_bit_identically(addr, m.native_model());
    server.shutdown();
}

#[test]
fn multi_lane_panic_with_requeue_restarts_and_streams_exactly_once() {
    let _scope = scenario();
    let (m, server) = serve(ServeConfig {
        max_batch: 2,
        max_queued: 8,
        restart_policy: RestartPolicy::Requeue,
        ..ServeConfig::default()
    });
    let addr = server.local_addr();
    let prompts = [vec![1u32, 2, 3], vec![9u32, 8]];
    let gen = 600usize;

    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            let p = p.clone();
            std::thread::spawn(move || {
                post(addr, "/v1/completions", &completion_body(&p, gen, true))
            })
        })
        .collect();
    wait_for_metrics(addr, |mx| mx.get("active").unwrap().as_u64() == Some(2), "both lanes live");

    // Next decode step panics with two lanes active: unattributable, so
    // the supervisor restarts and requeues both under their original ids.
    fault::arm_global(fault::STEP_PANIC, 1);

    for (p, h) in prompts.iter().zip(handles) {
        let resp = h.join().unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let events = sse_events(&resp.body);
        assert_eq!(events.last().unwrap(), "[DONE]", "requeued stream must still terminate");
        assert_eq!(
            streamed_tokens(&resp.body),
            reference_tokens(m.native_model(), p, gen),
            "replay suppression must hand out each token exactly once, bit-identically"
        );
    }
    let h = Json::parse(&get(addr, "/healthz").body).unwrap();
    assert_eq!(h.get("status").unwrap().as_str(), Some("ok"), "restart is not death");
    assert!(h.get("engine_restarts").unwrap().as_u64().unwrap() >= 1);
    wait_for_metrics(addr, |mx| mx.get("kv_bytes").unwrap().as_u64() == Some(0), "kv drained");
    assert_serves_bit_identically(addr, m.native_model());
    server.shutdown();
}

#[test]
fn restart_budget_exhaustion_flips_healthz_to_503() {
    let _scope = scenario();
    let (_m, server) = serve(ServeConfig {
        max_batch: 2,
        max_queued: 8,
        max_engine_restarts: 0,
        ..ServeConfig::default()
    });
    let addr = server.local_addr();

    let handles: Vec<_> = [vec![1u32, 2], vec![7u32, 7, 7]]
        .into_iter()
        .map(|p| {
            std::thread::spawn(move || {
                post(addr, "/v1/completions", &completion_body(&p, 600, true))
            })
        })
        .collect();
    wait_for_metrics(addr, |mx| mx.get("active").unwrap().as_u64() == Some(2), "both lanes live");
    fault::arm_global(fault::STEP_PANIC, 1);

    // Budget 0: the first unattributable panic is fatal. Both streams end
    // with an error event instead of [DONE].
    for h in handles {
        let resp = h.join().unwrap();
        let events = sse_events(&resp.body);
        assert_ne!(events.last().map(String::as_str), Some("[DONE]"));
        let last = Json::parse(events.last().unwrap()).unwrap();
        assert!(last.get("error").is_some(), "dying stream must carry an error event");
    }

    // /healthz reports the truth: 503, engine not alive.
    let t0 = Instant::now();
    loop {
        let h = get(addr, "/healthz");
        if h.status == 503 {
            let doc = Json::parse(&h.body).unwrap();
            assert_eq!(doc.get("status").unwrap().as_str(), Some("engine dead"));
            assert_eq!(doc.get("engine_alive").unwrap().as_bool(), Some(false));
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "healthz never flipped to 503");
        std::thread::sleep(Duration::from_millis(5));
    }
    let m = Json::parse(&get(addr, "/metrics").body).unwrap();
    assert!(m.get("failed").unwrap().as_u64().unwrap() >= 2);
    assert!(m.get("engine_restarts").unwrap().as_u64().unwrap() >= 1);

    // New work is refused with 503, not silently queued into a dead engine.
    let resp = post(addr, "/v1/completions", &completion_body(&[1], 4, false));
    assert_eq!(resp.status, 503, "{}", resp.body);
    server.shutdown();
}

#[test]
fn engine_stall_delays_but_never_corrupts_output() {
    let _scope = scenario();
    let (m, server) = serve(ServeConfig::default());
    let addr = server.local_addr();

    // A 1.5s stall injected into one decode step: the request takes
    // longer but the tokens are untouched.
    fault::arm_global(fault::ENGINE_STALL, 2);
    let prompt = [5u32, 1, 2];
    let resp = post(addr, "/v1/completions", &completion_body(&prompt, 6, false));
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(response_tokens(&resp.body), reference_tokens(m.native_model(), &prompt, 6));
    assert_serves_bit_identically(addr, m.native_model());
    server.shutdown();
}

#[test]
fn slow_socket_writes_do_not_corrupt_streams() {
    let _scope = scenario();
    let (m, server) = serve(ServeConfig::default());
    let addr = server.local_addr();

    // One SSE chunk write stalls 1s mid-stream; the client just sees a
    // pause, then the identical token sequence.
    fault::arm_global(fault::SLOW_WRITE, 2);
    let prompt = [2u32, 4, 6];
    let resp = post(addr, "/v1/completions", &completion_body(&prompt, 6, true));
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(sse_events(&resp.body).last().unwrap(), "[DONE]");
    assert_eq!(streamed_tokens(&resp.body), reference_tokens(m.native_model(), &prompt, 6));
    server.shutdown();
}

#[test]
fn kv_budget_flood_never_exceeds_budget_and_every_request_resolves() {
    let _scope = scenario();
    let m = model();
    // Budget: two fully grown request costs. Lanes admit one at a time,
    // combined page growth can brush the budget exactly (preemption
    // territory), and the queue absorbs or sheds the rest.
    let budget = {
        let probe = guidedquant::serve::Scheduler::new(m.native_model(), ServeConfig::default());
        probe.kv_request_cost_bytes(48 + 32) * 2
    };
    let cfg = ServeConfig {
        max_batch: 2,
        max_queued: 4,
        kv_budget_bytes: budget,
        ..ServeConfig::default()
    };
    let server = HttpServer::bind(m.clone(), cfg, "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    let vocab = m.native_model().cfg.vocab as u32;
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let prompt: Vec<u32> =
                (0..48).map(|j| ((i * 31 + j * 7) as u32) % vocab).collect();
            std::thread::spawn(move || {
                let resp = post(addr, "/v1/completions", &completion_body(&prompt, 32, false));
                (prompt, resp)
            })
        })
        .collect();

    // While the flood is in flight: the budget is a hard ceiling and the
    // health probe must keep answering.
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_millis(1500) {
        let mx = Json::parse(&get(addr, "/metrics").body).unwrap();
        let alloc = mx.get("kv_allocated_bytes").unwrap().as_u64().unwrap();
        assert!(alloc <= budget as u64, "kv_allocated_bytes {alloc} exceeded budget {budget}");
        assert_eq!(mx.get("kv_budget_bytes").unwrap().as_u64(), Some(budget as u64));
        assert_eq!(get(addr, "/healthz").status, 200, "healthz must stay live under flood");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Every request resolves: served bit-identically (preempted-then-
    // completed counts — replay suppression keeps it exact) or shed with
    // a computed Retry-After. No third outcome, no hang.
    let mut served = 0;
    for h in handles {
        let (prompt, resp) = h.join().unwrap();
        match resp.status {
            200 => {
                assert_eq!(
                    response_tokens(&resp.body),
                    reference_tokens(m.native_model(), &prompt, 32),
                    "flooded request diverged from the unloaded reference"
                );
                served += 1;
            }
            429 => assert_sane_retry_after(&resp),
            s => panic!("request resolved with unexpected status {s}: {}", resp.body),
        }
    }
    assert!(served >= 1, "the flood must not shed everything");
    let mx = Json::parse(&get(addr, "/metrics").body).unwrap();
    assert!(mx.get("kv_allocated_bytes").unwrap().as_u64().unwrap() <= budget as u64);
    assert_serves_bit_identically(addr, m.native_model());
    server.shutdown();
}

#[test]
fn brownout_clamps_tokens_and_flags_degraded_over_http() {
    let _scope = scenario();
    let m = model();
    // Budget ~ the long request's full cost / 0.89: the lane is admitted
    // (cost just under the high watermark) and its page growth alone
    // crosses the low watermark mid-decode — brownout territory.
    let budget = {
        let probe = guidedquant::serve::Scheduler::new(m.native_model(), ServeConfig::default());
        (probe.kv_request_cost_bytes(2 + 600) as f64 / 0.89) as usize
    };
    let cfg = ServeConfig {
        max_batch: 2,
        max_queued: 8,
        kv_budget_bytes: budget,
        ..ServeConfig::default()
    };
    let server = HttpServer::bind(m.clone(), cfg, "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    // Freeze the engine for 1.5s around decode step 470 — inside the
    // brownout window (low watermark crossed near step ~450) — so the
    // second request deterministically lands while pressure is high.
    fault::arm_global(fault::ENGINE_STALL, 470);
    let p_long = vec![1u32, 2];
    let long = {
        let p = p_long.clone();
        std::thread::spawn(move || post(addr, "/v1/completions", &completion_body(&p, 600, false)))
    };
    wait_for_metrics(
        addr,
        |mx| mx.get("kv_pressure").unwrap().as_f64().unwrap_or(0.0) >= 0.70,
        "kv pressure above the low watermark",
    );

    // Asks for 600 tokens; brownout must clamp it to 32 and say so.
    let p_short = [9u32, 1];
    let resp = post(addr, "/v1/completions", &completion_body(&p_short, 600, false));
    assert_eq!(resp.status, 200, "{}", resp.body);
    let doc = Json::parse(&resp.body).unwrap();
    assert_eq!(doc.get("degraded").unwrap().as_bool(), Some(true), "{}", resp.body);
    assert_eq!(doc.get("finish_reason").unwrap().as_str(), Some("length"));
    assert_eq!(doc.get("n_tokens").unwrap().as_u64(), Some(32));
    assert_eq!(
        response_tokens(&resp.body),
        reference_tokens(m.native_model(), &p_short, 32),
        "browned-out output must be bit-identical up to the clamp"
    );

    let long_resp = long.join().unwrap();
    assert_eq!(long_resp.status, 200, "{}", long_resp.body);
    let long_doc = Json::parse(&long_resp.body).unwrap();
    assert_eq!(long_doc.get("degraded").unwrap().as_bool(), Some(false));
    assert_eq!(response_tokens(&long_resp.body), reference_tokens(m.native_model(), &p_long, 600));
    wait_for_metrics(
        addr,
        |mx| mx.get("brownouts").unwrap().as_u64() == Some(1),
        "brownout counter",
    );
    assert_serves_bit_identically(addr, m.native_model());
    server.shutdown();
}

#[test]
fn kv_exhaust_fault_sheds_once_with_computed_retry_after() {
    let _scope = scenario();
    let (m, server) = serve(ServeConfig::default());
    let addr = server.local_addr();

    // No budget configured: the armed site reports spurious exhaustion at
    // exactly one admission check — the out-of-memory fault class without
    // the OOM. One 429, then business as usual.
    fault::arm_global(fault::KV_EXHAUST, 1);
    let resp = post(addr, "/v1/completions", &completion_body(&[1, 2, 3], 6, false));
    assert_eq!(resp.status, 429, "{}", resp.body);
    assert_sane_retry_after(&resp);
    wait_for_metrics(addr, |mx| mx.get("rejected").unwrap().as_u64() == Some(1), "shed counted");
    assert_eq!(get(addr, "/healthz").status, 200);
    assert_serves_bit_identically(addr, m.native_model());
    server.shutdown();
}

#[test]
fn slow_read_stalls_one_connection_not_the_server() {
    let _scope = scenario();
    let (m, server) = serve(ServeConfig::default());
    let addr = server.local_addr();

    // The slowloris fault class: one request body read stalls 1s on its
    // own connection thread. The response arrives late but bit-identical,
    // and the server answers health probes throughout.
    fault::arm_global(fault::SLOW_READ, 1);
    let prompt = [6u32, 5, 4];
    let t0 = Instant::now();
    let slow = std::thread::spawn(move || {
        post(addr, "/v1/completions", &completion_body(&prompt, 6, false))
    });
    assert_eq!(get(addr, "/healthz").status, 200, "health probe must not queue behind the stall");
    let resp = slow.join().unwrap();
    assert!(t0.elapsed() >= Duration::from_millis(900), "stall site never fired");
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(response_tokens(&resp.body), reference_tokens(m.native_model(), &prompt, 6));
    server.shutdown();
}

#[test]
fn prefix_evict_mid_decode_keeps_borrowers_bit_identical() {
    let _scope = scenario();
    let (m, server) = serve(ServeConfig::default());
    let addr = server.local_addr();

    // Warm the cache: a 130-token prompt donates two page-aligned chunks
    // into the prefix index when it finishes.
    let vocab = m.native_model().cfg.vocab as u32;
    let prompt: Vec<u32> = (0..130).map(|i| ((i * 13 + 7) as u32) % vocab).collect();
    let warm = post(addr, "/v1/completions", &completion_body(&prompt, 4, false));
    assert_eq!(warm.status, 200, "{}", warm.body);
    wait_for_metrics(
        addr,
        |mx| mx.get("prefix_cached_pages").unwrap().as_u64().unwrap_or(0) > 0,
        "prefix donation",
    );

    // A sharing request maps the cached prefix; the armed site then
    // force-clears the whole index on its next decode step, while that
    // borrower is mid-decode. The lane's own page references must carry
    // it to a bit-identical completion — eviction can never corrupt a
    // borrower.
    fault::arm_global(fault::PREFIX_EVICT, 1);
    let resp = post(addr, "/v1/completions", &completion_body(&prompt, 8, false));
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(
        response_tokens(&resp.body),
        reference_tokens(m.native_model(), &prompt, 8),
        "forced eviction corrupted a borrowing lane"
    );
    let mx = Json::parse(&get(addr, "/metrics").body).unwrap();
    assert!(mx.get("prefix_hits").unwrap().as_u64().unwrap() >= 1, "share must have hit");
    assert!(mx.get("prefill_tokens_saved").unwrap().as_u64().unwrap() >= 128);
    assert_eq!(get(addr, "/healthz").status, 200);
    assert_serves_bit_identically(addr, m.native_model());
    server.shutdown();
}

#[test]
fn predicted_deadline_shedding_rejects_doomed_requests_up_front() {
    let _scope = scenario();
    let (m, server) = serve(ServeConfig {
        max_batch: 1,
        max_queued: 8,
        ..ServeConfig::default()
    });
    let addr = server.local_addr();

    // Stall decode step 2 for 1.5s: the EWMA step time spikes to
    // hundreds of ms. A second long request parks in the queue during
    // the stall, so when the probe with `timeout_ms: 1` is evaluated
    // right after it, the predicted wait (queue depth x step EWMA)
    // dwarfs its deadline — shed up front, never enqueued.
    fault::arm_global(fault::ENGINE_STALL, 2);
    let p_a = vec![1u32, 2];
    let p_b = vec![7u32, 8];
    let a = {
        let p = p_a.clone();
        std::thread::spawn(move || post(addr, "/v1/completions", &completion_body(&p, 600, false)))
    };
    wait_for_metrics(addr, |mx| mx.get("active").unwrap().as_u64() == Some(1), "lane occupied");
    let b = {
        let p = p_b.clone();
        std::thread::spawn(move || post(addr, "/v1/completions", &completion_body(&p, 600, false)))
    };
    std::thread::sleep(Duration::from_millis(100)); // b enqueues before the probe

    let doomed = post(addr, "/v1/completions", &completion_body_deadline(&[5], 8, 1));
    assert_eq!(doomed.status, 429, "{}", doomed.body);
    assert_sane_retry_after(&doomed);
    assert!(
        doomed.body.contains("predicted queue wait"),
        "shed reason must name the prediction: {}",
        doomed.body
    );
    wait_for_metrics(
        addr,
        |mx| mx.get("shed_predicted_deadline").unwrap().as_u64() == Some(1),
        "deadline shed counter",
    );

    // The honestly admitted requests still complete bit-identically.
    for (h, p) in [(a, &p_a), (b, &p_b)] {
        let resp = h.join().unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        assert_eq!(response_tokens(&resp.body), reference_tokens(m.native_model(), p, 600));
    }
    assert_serves_bit_identically(addr, m.native_model());
    server.shutdown();
}
