//! Runtime integration: the AOT artifacts must execute from Rust and agree
//! numerically with the independent native forward — the deepest
//! correctness check in the repository (two implementations of the model,
//! one in JAX lowered to HLO, one in Rust, must produce the same loss).
//!
//! All tests skip gracefully when artifacts are not built.

use guidedquant::cfg::preset;
use guidedquant::model::{NativeModel, ParamStore};
use guidedquant::runtime::{Runtime, Value};
use guidedquant::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("runtime load"))
}

fn params(rt: &Runtime, seed: u64) -> ParamStore {
    let (cfg, _) = preset(&rt.manifest.model.name);
    ParamStore::init(&cfg, &mut Rng::new(seed))
}

fn tokens(rt: &Runtime, seed: u64) -> Vec<i32> {
    let bc = rt.manifest.batch;
    let vocab = rt.manifest.model.vocab;
    let mut rng = Rng::new(seed);
    (0..bc.tokens()).map(|_| rng.below(vocab) as i32).collect()
}

#[test]
fn fwd_loss_matches_native_forward() {
    let Some(rt) = runtime() else { return };
    let ps = params(&rt, 7);
    let toks = tokens(&rt, 1);
    let bc = rt.manifest.batch;

    let mut args = rt.param_args(&ps);
    args.push(Value::tokens(bc.batch, bc.seq, &toks));
    let outs = rt.artifact("fwd_loss").unwrap().execute(&args).unwrap();
    let artifact_loss = outs[0].scalar_f32().unwrap() as f64;

    // Native forward on the same tokens (row-per-sequence).
    let model = NativeModel::from_params(&ps);
    let mut native_loss = 0.0f64;
    for b in 0..bc.batch {
        let seq: Vec<u32> = toks[b * bc.seq..(b + 1) * bc.seq].iter().map(|&t| t as u32).collect();
        native_loss += model.loss_sum(&seq);
    }
    let rel = (artifact_loss - native_loss).abs() / native_loss.max(1e-9);
    assert!(
        rel < 2e-3,
        "artifact loss {artifact_loss} vs native {native_loss} (rel {rel})"
    );
}

#[test]
fn qa_artifacts_execute_and_order_sensibly() {
    let Some(rt) = runtime() else { return };
    let ps = params(&rt, 8);
    let toks = tokens(&rt, 2);
    let bc = rt.manifest.batch;
    let mut args = rt.param_args(&ps);
    args.push(Value::tokens(bc.batch, bc.seq, &toks));
    let loss16 = rt.artifact("fwd_loss").unwrap().execute(&args).unwrap()[0]
        .scalar_f32()
        .unwrap();
    let loss8 = rt.artifact("fwd_loss_qa8kv8").unwrap().execute(&args).unwrap()[0]
        .scalar_f32()
        .unwrap();
    let loss4 = rt.artifact("fwd_loss_qa4kv4").unwrap().execute(&args).unwrap()[0]
        .scalar_f32()
        .unwrap();
    // 8-bit activations barely move the loss; 4-bit moves it more.
    assert!((loss8 - loss16).abs() / loss16 < 0.05, "{loss16} vs {loss8}");
    assert!((loss4 - loss16).abs() >= (loss8 - loss16).abs() * 0.5, "{loss16} {loss8} {loss4}");
    assert!(loss4.is_finite());
}

#[test]
fn xtsx_demo_matches_native_gram() {
    let Some(rt) = runtime() else { return };
    let bc = rt.manifest.batch;
    let n = bc.tokens();
    let d = rt.manifest.model.d_model;
    let g = rt.manifest.groups + 1;
    let mut rng = Rng::new(3);
    let x = guidedquant::tensor::Mat::randn(n, d, 1.0, &mut rng);
    let s = guidedquant::tensor::Mat::from_fn(g, n, |_, _| rng.f32());
    let outs = rt
        .artifact("xtsx_demo")
        .unwrap()
        .execute(&[Value::from_mat(&x), Value::from_mat(&s)])
        .unwrap();
    let hs = outs[0].as_f32().unwrap();
    // Native check for group 1.
    let k = 1usize;
    let mut want = guidedquant::tensor::Mat::zeros(d, d);
    for i in 0..n {
        let sv = s.at(k, i);
        for a in 0..d {
            let base = sv * x.at(i, a);
            for b in 0..d {
                *want.at_mut(a, b) += base * x.at(i, b);
            }
        }
    }
    let block = &hs[k * d * d..(k + 1) * d * d];
    guidedquant::testing::assert_close(block, &want.data, 5e-3, 5e-3).unwrap();
}

#[test]
fn lut_matmul_demo_matches_native_dequant_matmul() {
    let Some(rt) = runtime() else { return };
    let bc = rt.manifest.batch;
    let n = bc.tokens();
    let d = rt.manifest.model.d_model;
    let m = 16usize;
    let mut rng = Rng::new(4);
    let x = guidedquant::tensor::Mat::randn(n, d, 1.0, &mut rng);
    let codes: Vec<i32> = (0..d * d).map(|_| rng.below(m) as i32).collect();
    let cb = guidedquant::tensor::Mat::randn(d, m, 1.0, &mut rng);
    let outs = rt
        .artifact("lut_matmul_demo")
        .unwrap()
        .execute(&[
            Value::from_mat(&x),
            Value::I32(codes.clone(), vec![d, d]),
            Value::from_mat(&cb),
        ])
        .unwrap();
    let y = outs[0].as_f32().unwrap();
    // Native: decode then matmul.
    let w_hat = guidedquant::tensor::Mat::from_fn(d, d, |i, j| cb.at(j, codes[i * d + j] as usize));
    let want = guidedquant::tensor::ops::matmul(&x, &w_hat);
    guidedquant::testing::assert_close(y, &want.data, 5e-3, 5e-3).unwrap();
}

#[test]
fn train_step_decreases_loss_deterministically() {
    let Some(rt) = runtime() else { return };
    let ps = params(&rt, 9);
    let bc = rt.manifest.batch;
    let toks = tokens(&rt, 5);
    let n_p = ps.cfg.param_specs().len();
    let zeros: Vec<Value> = ps
        .cfg
        .param_specs()
        .iter()
        .map(|s| {
            if s.cols == 1 && s.name.ends_with("norm") {
                Value::F32(vec![0.0; s.rows], vec![s.rows])
            } else {
                Value::F32(vec![0.0; s.rows * s.cols], vec![s.rows, s.cols])
            }
        })
        .collect();
    let mut args = rt.param_args(&ps);
    args.extend(zeros.clone());
    args.extend(zeros);
    args.push(Value::Scalar(0.0));
    args.push(Value::tokens(bc.batch, bc.seq, &toks));
    let artifact = rt.artifact("train_step").unwrap();
    let o1 = artifact.execute(&args).unwrap();
    let o2 = artifact.execute(&args).unwrap();
    assert_eq!(o1[0].scalar_f32().unwrap(), o2[0].scalar_f32().unwrap(), "nondeterministic");
    assert_eq!(o1.len(), 1 + 3 * n_p + 1);
    assert_eq!(o1[1 + 3 * n_p].scalar_f32().unwrap(), 1.0, "step counter");
}
