//! HTTP front-end integration: a real [`HttpServer`] bound to port 0 on
//! the tiny preset, driven by raw `TcpStream` clients. Verifies routing,
//! the completion request/response schema, SSE streaming, bit-identity of
//! served tokens against the in-process scheduler path, admission-control
//! status codes (400/429), and graceful-shutdown draining.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use guidedquant::cfg::{preset, ServeConfig};
use guidedquant::model::{NativeModel, ParamStore};
use guidedquant::serve::{
    build_serving_set, generate_scheduled, HttpServer, ModelSet, ServeFormat,
};
use guidedquant::util::json::Json;
use guidedquant::util::Rng;

fn model(format: ServeFormat) -> Arc<ModelSet> {
    let (cfg, _) = preset("tiny");
    let ps = ParamStore::init(&cfg, &mut Rng::new(0));
    Arc::new(build_serving_set(&ps, None, format, 4).unwrap())
}

fn serve(format: ServeFormat, cfg: ServeConfig) -> (Arc<ModelSet>, HttpServer) {
    let m = model(format);
    let server = HttpServer::bind(m.clone(), cfg, "127.0.0.1:0").unwrap();
    (m, server)
}

struct Response {
    status: u16,
    body: String,
}

/// Send one raw HTTP request and read the full response (Content-Length
/// or chunked transfer encoding both handled).
fn request(addr: SocketAddr, raw: &str) -> Response {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        let (k, v) = t.split_once(':').unwrap();
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let chunked = headers.iter().any(|(k, v)| k == "transfer-encoding" && v.contains("chunked"));
    let body = if chunked {
        let mut out = String::new();
        loop {
            let mut sz = String::new();
            r.read_line(&mut sz).unwrap();
            let n = usize::from_str_radix(sz.trim(), 16).unwrap();
            let mut buf = vec![0u8; n + 2];
            r.read_exact(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            out.push_str(std::str::from_utf8(&buf[..n]).unwrap());
        }
        out
    } else {
        let cl = headers.iter().find(|(k, _)| k == "content-length").expect("content-length");
        let n: usize = cl.1.parse().unwrap();
        let mut buf = vec![0u8; n];
        r.read_exact(&mut buf).unwrap();
        String::from_utf8(buf).unwrap()
    };
    Response { status, body }
}

fn get(addr: SocketAddr, path: &str) -> Response {
    request(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"))
}

fn post(addr: SocketAddr, path: &str, body: &str) -> Response {
    request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn completion_body(prompt: &[u32], max_tokens: usize, stream: bool) -> String {
    let toks: Vec<Json> = prompt.iter().map(|&t| Json::from(t)).collect();
    Json::object()
        .with("prompt", toks)
        .with("max_tokens", max_tokens)
        .with("stream", stream)
        .encode()
}

fn response_tokens(body: &str) -> Vec<u32> {
    let doc = Json::parse(body).unwrap();
    let arr = doc.get("tokens").unwrap().as_arr().unwrap().to_vec();
    arr.iter().map(|t| t.as_u64().unwrap() as u32).collect()
}

/// `data: {...}` SSE events from a streamed response body.
fn sse_events(body: &str) -> Vec<String> {
    body.lines().filter(|l| l.starts_with("data: ")).map(|l| l[6..].to_string()).collect()
}

fn reference_tokens(m: &NativeModel, prompt: &[u32], gen: usize) -> Vec<u32> {
    let (outs, _) =
        generate_scheduled(m, &[prompt.to_vec()], gen, 1, ServeConfig::default()).unwrap();
    outs.into_iter().next().unwrap()
}

/// Poll `/metrics` until `pred` holds (the engine thread publishes gauges
/// after every step, so transitions land within a few steps).
fn wait_for_metrics(addr: SocketAddr, pred: impl Fn(&Json) -> bool, what: &str) {
    let t0 = Instant::now();
    loop {
        let m = Json::parse(&get(addr, "/metrics").body).unwrap();
        if pred(&m) {
            return;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn healthz_metrics_and_routing() {
    let (_m, server) = serve(ServeFormat::Fp32, ServeConfig::default());
    let addr = server.local_addr();

    let h = get(addr, "/healthz");
    assert_eq!(h.status, 200);
    let h = Json::parse(&h.body).unwrap();
    assert_eq!(h.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(h.get("model").unwrap().as_str(), Some("tiny"));
    // Liveness is truthful, not hardcoded: the supervised engine reports
    // its alive flag and restart count.
    assert_eq!(h.get("engine_alive").unwrap().as_bool(), Some(true));
    assert_eq!(h.get("engine_restarts").unwrap().as_u64(), Some(0));

    let m = get(addr, "/metrics");
    assert_eq!(m.status, 200);
    let m = Json::parse(&m.body).unwrap();
    let gauges = [
        "queued",
        "active",
        "completed",
        "rejected",
        "ttft_ms",
        "token_ms",
        "kv_bytes",
        "kv_allocated_bytes",
        "cancelled",
        "timed_out",
        "failed",
        "engine_restarts",
        "precision_downshifts",
        "completed_by_precision",
    ];
    for key in gauges {
        assert!(m.get(key).is_some(), "metrics missing `{key}`: {}", m.encode());
    }
    assert_eq!(m.get("kv_dtype").unwrap().as_str(), Some("f32"));

    assert_eq!(get(addr, "/nope").status, 404);
    assert_eq!(get(addr, "/v1/completions").status, 405, "GET on a POST route");
    server.shutdown();
}

#[test]
fn per_request_timeout_returns_partial_output_as_timeout() {
    let (_m, server) = serve(ServeFormat::Fp32, ServeConfig::default());
    let addr = server.local_addr();
    let body = Json::object()
        .with("prompt", vec![Json::from(1u32), Json::from(2u32)])
        .with("max_tokens", 4000usize)
        .with("timeout_ms", 80u64)
        .encode();
    let resp = post(addr, "/v1/completions", &body);
    assert_eq!(resp.status, 200, "{}", resp.body);
    let doc = Json::parse(&resp.body).unwrap();
    assert_eq!(doc.get("finish_reason").unwrap().as_str(), Some("timeout"));
    let tokens = response_tokens(&resp.body);
    assert!(!tokens.is_empty(), "deadline eviction should keep partial output");
    assert!(tokens.len() < 4000, "the deadline must fire well before max_tokens");
    wait_for_metrics(
        addr,
        |m| m.get("timed_out").unwrap().as_u64() == Some(1),
        "timed_out counter",
    );
    // The expired lane released its KV pages.
    wait_for_metrics(addr, |m| m.get("kv_bytes").unwrap().as_u64() == Some(0), "kv freed");
    server.shutdown();
}

#[test]
fn client_disconnect_cancels_the_lane_and_frees_kv() {
    let (m, server) = serve(ServeFormat::Fp32, ServeConfig::default());
    let addr = server.local_addr();

    // Streamed request with a long budget; read a few bytes, then hang up.
    {
        let body = completion_body(&[5u32, 6, 7], 4000, true);
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s.write_all(
            format!(
                "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        let mut first = [0u8; 64];
        s.read_exact(&mut first).unwrap(); // the stream is live
        drop(s); // mid-stream hang-up
    }

    // The failed SSE write turns into ToEngine::Cancel: the lane is
    // evicted, counted, and its KV pages return to the arena.
    wait_for_metrics(
        addr,
        |mx| {
            mx.get("cancelled").unwrap().as_u64() == Some(1)
                && mx.get("active").unwrap().as_u64() == Some(0)
                && mx.get("kv_bytes").unwrap().as_u64() == Some(0)
        },
        "disconnect cancellation",
    );

    // A fault-free follow-up is served bit-identically: the abandoned lane
    // left no residue in the scheduler.
    let prompt = [5u32, 6, 7];
    let resp = post(addr, "/v1/completions", &completion_body(&prompt, 5, false));
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(response_tokens(&resp.body), reference_tokens(m.native_model(), &prompt, 5));
    server.shutdown();
}

#[test]
fn blocking_completion_is_bit_identical_to_generate_scheduled() {
    let (m, server) = serve(ServeFormat::NonUniformScalar, ServeConfig::default());
    let addr = server.local_addr();
    let prompt = [3u32, 17, 99, 5];
    let want = reference_tokens(m.native_model(), &prompt, 6);

    let resp = post(addr, "/v1/completions", &completion_body(&prompt, 6, false));
    assert_eq!(resp.status, 200, "{}", resp.body);
    let doc = Json::parse(&resp.body).unwrap();
    assert_eq!(response_tokens(&resp.body), want, "served tokens diverged");
    assert_eq!(doc.get("n_tokens").unwrap().as_u64(), Some(6));
    assert_eq!(doc.get("finish_reason").unwrap().as_str(), Some("length"));
    let met = doc.get("metrics").unwrap();
    assert!(met.get("ttft_ms").unwrap().as_f64().unwrap() >= 0.0);
    server.shutdown();
}

#[test]
fn streamed_completion_matches_blocking_and_terminates() {
    let (m, server) = serve(ServeFormat::NonUniformScalar, ServeConfig::default());
    let addr = server.local_addr();
    let prompt = [1u32, 2, 3, 4];
    let want = reference_tokens(m.native_model(), &prompt, 8);

    let resp = post(addr, "/v1/completions", &completion_body(&prompt, 8, true));
    assert_eq!(resp.status, 200);
    let events = sse_events(&resp.body);
    assert_eq!(events.len(), 10, "8 tokens + done + [DONE]: {events:?}");
    assert_eq!(events.last().unwrap(), "[DONE]", "stream must end with the terminator");
    let done = Json::parse(&events[events.len() - 2]).unwrap();
    assert_eq!(done.get("done").unwrap().as_bool(), Some(true));
    assert_eq!(done.get("n_tokens").unwrap().as_u64(), Some(8));
    let streamed: Vec<u32> = events[..events.len() - 2]
        .iter()
        .map(|e| {
            let ev = Json::parse(e).unwrap();
            ev.get("token").unwrap().as_u64().unwrap() as u32
        })
        .collect();
    assert_eq!(streamed, want, "streamed tokens diverged from the scheduler path");

    // The non-streamed variant of the same request returns the same tokens.
    let blocking = post(addr, "/v1/completions", &completion_body(&prompt, 8, false));
    assert_eq!(response_tokens(&blocking.body), want);
    server.shutdown();
}

#[test]
fn concurrent_clients_are_all_served_bit_identically() {
    // Four clients race into the continuous batch; each response must
    // still be exactly the single-prompt scheduler output (batch
    // composition never changes per-lane arithmetic).
    let (m, server) = serve(
        ServeFormat::Fp32,
        ServeConfig { max_batch: 3, max_queued: 8, ..ServeConfig::default() },
    );
    let addr = server.local_addr();
    let mut rng = Rng::new(11);
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|i| (0..(2 + i % 3)).map(|_| rng.below(m.native_model().cfg.vocab) as u32).collect())
        .collect();
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            let p = p.clone();
            std::thread::spawn(move || {
                let resp = post(addr, "/v1/completions", &completion_body(&p, 5, false));
                assert_eq!(resp.status, 200, "{}", resp.body);
                response_tokens(&resp.body)
            })
        })
        .collect();
    let got: Vec<Vec<u32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (p, tokens) in prompts.iter().zip(&got) {
        assert_eq!(tokens, &reference_tokens(m.native_model(), p, 5), "prompt {p:?}");
    }
    server.shutdown();
}

#[test]
fn invalid_requests_get_400() {
    let (_m, server) = serve(ServeFormat::Fp32, ServeConfig::default());
    let addr = server.local_addr();
    for body in [
        "{oops",                                  // malformed json
        "{\"max_tokens\": 4}",                    // missing prompt
        "{\"prompt\": \"text\"}",                 // wrong type
        "{\"prompt\": []}",                       // empty prompt
        "{\"prompt\": [99999]}",                  // out of vocab
        "{\"prompt\": [1], \"max_tokens\": 1e9}", // over the gen cap
    ] {
        let resp = post(addr, "/v1/completions", body);
        assert_eq!(resp.status, 400, "`{body}` -> {}", resp.body);
        let doc = Json::parse(&resp.body).unwrap();
        assert!(doc.get("error").is_some(), "400 body must carry an error: {}", resp.body);
    }
    // Post-error the server still serves.
    let ok = post(addr, "/v1/completions", &completion_body(&[1, 2], 2, false));
    assert_eq!(ok.status, 200);
    server.shutdown();
}

#[test]
fn full_queue_gets_429_and_shutdown_drains_in_flight_lanes() {
    // One active lane + one queued slot: the third concurrent request must
    // bounce with 429, while the accepted ones run to completion even
    // though shutdown() fires mid-generation.
    let (_m, server) = serve(
        ServeFormat::Fp32,
        ServeConfig { max_batch: 1, max_queued: 1, ..ServeConfig::default() },
    );
    let addr = server.local_addr();

    // A: long streamed request; occupies the single lane.
    let a = std::thread::spawn(move || {
        post(addr, "/v1/completions", &completion_body(&[1, 2], 600, true))
    });
    wait_for_metrics(addr, |m| m.get("active").unwrap().as_u64() == Some(1), "A active");

    // B: fills the single queue slot.
    let b = std::thread::spawn(move || {
        post(addr, "/v1/completions", &completion_body(&[3], 4, false))
    });
    wait_for_metrics(addr, |m| m.get("queued").unwrap().as_u64() == Some(1), "B queued");

    // C: queue full -> 429 with an error body, never enqueued.
    let c = post(addr, "/v1/completions", &completion_body(&[4], 4, false));
    assert_eq!(c.status, 429, "{}", c.body);
    assert!(Json::parse(&c.body).unwrap().get("error").is_some());
    let m = Json::parse(&get(addr, "/metrics").body).unwrap();
    assert!(m.get("rejected").unwrap().as_u64().unwrap() >= 1);

    // Graceful shutdown while A streams and B waits: both must complete.
    server.shutdown();
    let a = a.join().unwrap();
    assert_eq!(a.status, 200);
    let events = sse_events(&a.body);
    assert_eq!(events.last().unwrap(), "[DONE]", "A was truncated by shutdown");
    assert_eq!(events.len(), 602, "600 tokens + done + [DONE]");
    let b = b.join().unwrap();
    assert_eq!(b.status, 200);
    assert_eq!(response_tokens(&b.body).len(), 4, "queued request must drain");
}

fn precision_body(prompt: &[u32], max_tokens: usize, stream: bool, precision: u8) -> String {
    let toks: Vec<Json> = prompt.iter().map(|&t| Json::from(t)).collect();
    Json::object()
        .with("prompt", toks)
        .with("max_tokens", max_tokens)
        .with("stream", stream)
        .with("precision", precision as u32)
        .encode()
}

#[test]
fn v1_capabilities_reports_format_and_precisions() {
    let (_m, server) = serve(ServeFormat::AnyPrecision, ServeConfig::default());
    let addr = server.local_addr();
    let c = get(addr, "/v1/capabilities");
    assert_eq!(c.status, 200, "{}", c.body);
    let c = Json::parse(&c.body).unwrap();
    assert_eq!(c.get("api").unwrap().as_str(), Some("v1"));
    assert_eq!(c.get("format").unwrap().as_str(), Some("anyprec"));
    let precs: Vec<u64> = c
        .get("precisions")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_u64().unwrap())
        .collect();
    assert_eq!(precs, vec![2, 3, 4], "one anyprec artifact serves every plane prefix");
    assert_eq!(c.get("default_precision").unwrap().as_u64(), Some(4), "0 resolves to native");
    assert_eq!(c.get("precision_floor").unwrap().as_u64(), Some(0), "downshift rung off");
    assert_eq!(c.get("kv_dtype").unwrap().as_str(), Some("f32"));
    assert_eq!(c.get("prefix_cache").unwrap().as_bool(), Some(true));
    assert_eq!(c.get("kv_budget_bytes").unwrap().as_u64(), Some(0));
    assert!(c.get("max_batch").unwrap().as_u64().unwrap() >= 1);
    assert!(c.get("max_gen_tokens").unwrap().as_u64().unwrap() >= 1);
    assert_eq!(post(addr, "/v1/capabilities", "{}").status, 405);
    server.shutdown();
}

#[test]
fn per_request_precision_is_honored_and_reported() {
    let (m, server) = serve(ServeFormat::AnyPrecision, ServeConfig::default());
    let addr = server.local_addr();
    let prompt = [3u32, 17, 9];
    // References decode through the per-precision views directly: the
    // serving contract is bit-identity to the model the label names.
    let want4 = reference_tokens(m.get(4).unwrap(), &prompt, 6);
    let want2 = reference_tokens(m.get(2).unwrap(), &prompt, 6);

    // No "precision" field: the server default (native 4-bit).
    let resp = post(addr, "/v1/completions", &completion_body(&prompt, 6, false));
    assert_eq!(resp.status, 200, "{}", resp.body);
    let doc = Json::parse(&resp.body).unwrap();
    assert_eq!(doc.get("precision").unwrap().as_u64(), Some(4));
    assert_eq!(response_tokens(&resp.body), want4);

    // Explicit 2-bit: the coarse plane-prefix view of the same artifact.
    let resp = post(addr, "/v1/completions", &precision_body(&prompt, 6, false, 2));
    assert_eq!(resp.status, 200, "{}", resp.body);
    let doc = Json::parse(&resp.body).unwrap();
    assert_eq!(doc.get("precision").unwrap().as_u64(), Some(2));
    assert_eq!(response_tokens(&resp.body), want2, "2-bit request served by the wrong view");

    // Streamed 2-bit: the done event reports the effective precision and
    // the streamed tokens match the blocking path.
    let resp = post(addr, "/v1/completions", &precision_body(&prompt, 6, true, 2));
    assert_eq!(resp.status, 200);
    let events = sse_events(&resp.body);
    let done = Json::parse(&events[events.len() - 2]).unwrap();
    assert_eq!(done.get("precision").unwrap().as_u64(), Some(2));
    let streamed: Vec<u32> = events[..events.len() - 2]
        .iter()
        .map(|e| Json::parse(e).unwrap().get("token").unwrap().as_u64().unwrap() as u32)
        .collect();
    assert_eq!(streamed, want2);

    // An unsupported precision is a client error listing the bank.
    let resp = post(addr, "/v1/completions", &precision_body(&prompt, 6, false, 7));
    assert_eq!(resp.status, 400, "{}", resp.body);
    let err = Json::parse(&resp.body).unwrap();
    let err = err.get("error").unwrap();
    assert_eq!(err.get("type").unwrap().as_str(), Some("invalid_request"));
    assert!(err.get("message").unwrap().as_str().unwrap().contains('2'), "{}", resp.body);

    // Per-precision completion counters add up; nothing was downshifted.
    wait_for_metrics(addr, |mx| mx.get("completed").unwrap().as_u64() == Some(3), "completions");
    let mx = Json::parse(&get(addr, "/metrics").body).unwrap();
    let by = mx.get("completed_by_precision").unwrap();
    assert_eq!(by.get("4").unwrap().as_u64(), Some(1), "{}", mx.encode());
    assert_eq!(by.get("2").unwrap().as_u64(), Some(2), "{}", mx.encode());
    assert_eq!(mx.get("precision_downshifts").unwrap().as_u64(), Some(0));
    server.shutdown();
}

#[test]
fn error_envelope_v1_and_legacy_accept_fallback() {
    let (_m, server) = serve(ServeFormat::Fp32, ServeConfig::default());
    let addr = server.local_addr();

    // v1 default: every error status carries the structured envelope.
    let resp = post(addr, "/v1/completions", "{oops");
    assert_eq!(resp.status, 400);
    let err = Json::parse(&resp.body).unwrap();
    let err = err.get("error").unwrap();
    assert_eq!(err.get("type").unwrap().as_str(), Some("invalid_request"), "{}", resp.body);
    assert!(err.get("message").unwrap().as_str().is_some());
    assert_eq!(err.get("retry_after_s").unwrap().as_u64(), Some(0));

    let nf = Json::parse(&get(addr, "/nope").body).unwrap();
    assert_eq!(nf.get("error").unwrap().get("type").unwrap().as_str(), Some("not_found"));
    let mna = Json::parse(&get(addr, "/v1/completions").body).unwrap();
    assert_eq!(
        mna.get("error").unwrap().get("type").unwrap().as_str(),
        Some("method_not_allowed")
    );

    // Pre-v1 clients opt back into the plain-string body per request.
    let body = "{oops";
    let resp = request(
        addr,
        &format!(
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nAccept: application/vnd.gq.v0+json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    );
    assert_eq!(resp.status, 400);
    let doc = Json::parse(&resp.body).unwrap();
    assert!(
        doc.get("error").unwrap().as_str().is_some(),
        "legacy body must be a plain string: {}",
        resp.body
    );
    server.shutdown();
}
