//! Serving integration: every quantized serving format must (a) track the
//! fp32 model's outputs at 4 bits, (b) honor the storage ordering of
//! Table 2, and (c) generate deterministically under the batched engine.

use guidedquant::cfg::preset;
use guidedquant::model::{NativeModel, ParamStore};
use guidedquant::serve::{build_serving_model, generate_batch, ServeFormat};
use guidedquant::util::Rng;

fn params() -> ParamStore {
    let (cfg, _) = preset("tiny");
    ParamStore::init(&cfg, &mut Rng::new(0))
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        dot += *x as f64 * *y as f64;
        na += (*x as f64).powi(2);
        nb += (*y as f64).powi(2);
    }
    dot / (na.sqrt() * nb.sqrt()).max(1e-12)
}

#[test]
fn all_formats_track_fp32_at_4_bits() {
    let ps = params();
    let toks = [3u32, 17, 99, 5, 250];
    let fp = NativeModel::from_params(&ps).forward_sequence(&toks);
    for format in [
        ServeFormat::UniformScalar,
        ServeFormat::NonUniformScalar,
        ServeFormat::Vector,
        ServeFormat::Trellis,
    ] {
        let m = build_serving_model(&ps, None, format, 4).unwrap();
        let got = m.forward_sequence(&toks);
        let cos = cosine(&got.data, &fp.data);
        // Trellis/vector at 4 bits are lossier than scalar but must still
        // be strongly aligned on a tiny model.
        let floor = match format {
            ServeFormat::UniformScalar | ServeFormat::NonUniformScalar => 0.93,
            _ => 0.80,
        };
        assert!(cos > floor, "{format:?} cosine {cos}");
    }
}

#[test]
fn storage_ordering_matches_table2() {
    let ps = params();
    let fp = build_serving_model(&ps, None, ServeFormat::Fp32, 16).unwrap();
    let u2 = build_serving_model(&ps, None, ServeFormat::UniformScalar, 2).unwrap();
    let u4 = build_serving_model(&ps, None, ServeFormat::UniformScalar, 4).unwrap();
    let lut4 = build_serving_model(&ps, None, ServeFormat::NonUniformScalar, 4).unwrap();
    assert!(u2.linear_storage_bytes() < u4.linear_storage_bytes());
    assert!(u4.linear_storage_bytes() < fp.linear_storage_bytes() / 4);
    // LUT adds per-channel codebooks but stays well below fp32.
    assert!(lut4.linear_storage_bytes() < fp.linear_storage_bytes() / 3);
}

#[test]
fn engine_scales_with_workers_and_stays_deterministic() {
    let ps = params();
    let m = build_serving_model(&ps, None, ServeFormat::NonUniformScalar, 4).unwrap();
    let mut rng = Rng::new(5);
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|_| (0..8).map(|_| rng.below(m.cfg.vocab) as u32).collect())
        .collect();
    let (o1, s1) = generate_batch(&m, &prompts, 12, 1).unwrap();
    let (o2, s2) = generate_batch(&m, &prompts, 12, 4).unwrap();
    assert_eq!(o1, o2, "worker count changed generations");
    assert_eq!(s1.total_tokens, 48);
    assert!(s2.tok_per_sec > 0.0);
}

#[test]
fn quantized_generation_overlaps_fp_generation() {
    // At 4 bits the quantized tiny model should often agree with fp32 on
    // greedy tokens (soft check: > 40% agreement over short horizon).
    let ps = params();
    let fp = build_serving_model(&ps, None, ServeFormat::Fp32, 16).unwrap();
    let q = build_serving_model(&ps, None, ServeFormat::NonUniformScalar, 4).unwrap();
    let prompts = vec![vec![1u32, 2, 3, 4]];
    let (a, _) = generate_batch(&fp, &prompts, 16, 1).unwrap();
    let (b, _) = generate_batch(&q, &prompts, 16, 1).unwrap();
    let agree = a[0].iter().zip(&b[0]).filter(|(x, y)| x == y).count();
    assert!(agree >= 6, "only {agree}/16 tokens agreed");
}

#[test]
fn scheduler_is_bit_identical_to_per_sequence_on_quantized_models() {
    // The continuous-batching scheduler must produce EXACTLY the greedy
    // tokens of the per-sequence reference path, for every serving format,
    // including when the batch is narrower than the request count
    // (mid-flight eviction + splicing).
    use guidedquant::cfg::ServeConfig;
    use guidedquant::serve::{generate_per_sequence, generate_scheduled, random_prompts};

    let ps = params();
    for format in [
        ServeFormat::Fp32,
        ServeFormat::UniformScalar,
        ServeFormat::NonUniformScalar,
        ServeFormat::Vector,
        ServeFormat::Trellis,
    ] {
        let m = build_serving_model(&ps, None, format, 4).unwrap();
        let prompts = random_prompts(m.cfg.vocab, 5, 6, 9);
        let (want, _) = generate_per_sequence(&m, &prompts, 8, 2).unwrap();
        let (full, _) = generate_batch(&m, &prompts, 8, 2).unwrap();
        assert_eq!(full, want, "{format:?}: full-width batch diverged");
        let cfg = ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() };
        let (narrow, stats) = generate_scheduled(&m, &prompts, 8, 1, cfg).unwrap();
        assert_eq!(narrow, want, "{format:?}: narrow batch diverged");
        assert!(stats.batch_occupancy > 1.0, "{format:?}: batching never engaged");
        // Chunked prefill (default) and the scalar-prefill reference path
        // must agree bitwise, per format.
        let cfg = ServeConfig {
            max_batch: 2,
            max_queued: 8,
            scalar_prefill: true,
            ..ServeConfig::default()
        };
        let (scalar_pre, _) = generate_scheduled(&m, &prompts, 8, 1, cfg).unwrap();
        assert_eq!(scalar_pre, want, "{format:?}: scalar-prefill path diverged");
    }
}

#[test]
fn long_context_decode_is_bit_identical_across_kv_page_boundaries() {
    // Every serving format must keep scheduler output EXACTLY equal to the
    // per-sequence scalar path when sequences grow past a KV page
    // (KV_PAGE_POS positions), exercising page-boundary crossings, paged
    // batched attention, and mid-flight eviction with page recycling.
    // Run under GQ_THREADS=1 (CI determinism job) and the default pool
    // width, results must be identical.
    use guidedquant::cfg::ServeConfig;
    use guidedquant::model::KV_PAGE_POS;
    use guidedquant::serve::{generate_per_sequence, generate_scheduled, random_prompts};

    let ps = params();
    let gen = KV_PAGE_POS + 6; // prompts are short, so decode crosses the boundary
    for format in [
        ServeFormat::Fp32,
        ServeFormat::UniformScalar,
        ServeFormat::NonUniformScalar,
        ServeFormat::Vector,
        ServeFormat::Trellis,
    ] {
        let m = build_serving_model(&ps, None, format, 4).unwrap();
        let prompts = random_prompts(m.cfg.vocab, 3, 3, 13);
        let (want, _) = generate_per_sequence(&m, &prompts, gen, 2).unwrap();
        let cfg = ServeConfig { max_batch: 2, max_queued: 8, ..ServeConfig::default() };
        let (got, _) = generate_scheduled(&m, &prompts, gen, 2, cfg).unwrap();
        assert_eq!(got, want, "{format:?} diverged past the page boundary");
    }
}

#[test]
fn streaming_matches_batch_outputs() {
    use guidedquant::cfg::ServeConfig;
    use guidedquant::serve::generate_scheduled_streaming;
    let ps = params();
    let m = build_serving_model(&ps, None, ServeFormat::NonUniformScalar, 4).unwrap();
    let prompts = vec![vec![1u32, 2, 3], vec![4u32, 5]];
    let mut streamed = vec![Vec::new(); prompts.len()];
    let cfg = ServeConfig { max_batch: 2, max_queued: 4, ..ServeConfig::default() };
    let (outs, _) = generate_scheduled_streaming(&m, &prompts, 6, 1, cfg, |id, tok| {
        streamed[id as usize].push(tok);
    })
    .unwrap();
    assert_eq!(streamed, outs);
}

#[test]
fn empty_prompts_are_rejected_by_both_paths() {
    use guidedquant::serve::generate_per_sequence;
    let ps = params();
    let m = build_serving_model(&ps, None, ServeFormat::NonUniformScalar, 4).unwrap();
    let prompts = vec![vec![]];
    assert!(generate_batch(&m, &prompts, 4, 1).is_err());
    assert!(generate_per_sequence(&m, &prompts, 4, 1).is_err());
}
