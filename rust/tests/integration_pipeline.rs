//! Pipeline integration: a miniature end-to-end run (train → calib →
//! quantize → eval) asserting the paper's qualitative claims hold on the
//! tiny preset: training reduces perplexity, quantization degrades it
//! gracefully, and GuidedQuant does not hurt at 2 bits.

use guidedquant::cfg::{PipelineConfig, QuantConfig, QuantMethod};
use guidedquant::coordinator::Pipeline;
use guidedquant::data::Split;

fn pipeline() -> Option<Pipeline> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let cfg = PipelineConfig {
        model: "tiny".into(),
        artifacts_dir: dir.parent().unwrap().to_str().unwrap().to_string(),
        out_dir: std::env::temp_dir()
            .join(format!("gq_it_pipeline_{}", std::process::id()))
            .to_str()
            .unwrap()
            .to_string(),
        train_steps: 80,
        calib_batches: 4,
        eval_batches: 6,
        ..Default::default()
    };
    Some(Pipeline::new(cfg).unwrap())
}

#[test]
fn full_pipeline_claims() {
    let Some(p) = pipeline() else { return };
    let mut ps = p.init_params();
    let ppl_untrained = p.perplexity(&ps, Split::Eval, "fwd_loss").unwrap();
    let losses = p.train(&mut ps, p.cfg.train_steps, 0).unwrap();
    assert_eq!(losses.len(), 80);
    assert!(
        losses.last().unwrap() < &(losses.first().unwrap() - 0.2),
        "training did not reduce loss: {losses:?}"
    );
    let ppl_fp = p.perplexity(&ps, Split::Eval, "fwd_loss").unwrap();
    assert!(ppl_fp < 0.8 * ppl_untrained, "training did not cut ppl: {ppl_untrained} -> {ppl_fp}");

    let stats = p.calib(&ps, true).unwrap();
    assert_eq!(stats.layers.len(), ps.cfg.linear_specs().len());

    // 4-bit quantization should be nearly lossless on the tiny model.
    let q4 = p
        .quantize(&ps, &stats, &QuantConfig::with(QuantMethod::Lnq, 4, 4))
        .unwrap();
    let ppl_q4 = p.perplexity(&p.apply_quantized(&ps, &q4), Split::Eval, "fwd_loss").unwrap();
    assert!(ppl_q4 < ppl_fp * 1.1, "4-bit hurt too much: {ppl_fp} -> {ppl_q4}");

    // 2-bit: GuidedQuant should be no worse than plain LNQ (paper claim),
    // with a small tolerance for tiny-model noise.
    let lnq2 = p
        .quantize(&ps, &stats, &QuantConfig::with(QuantMethod::Lnq, 2, 0))
        .unwrap();
    let gq2 = p
        .quantize(&ps, &stats, &QuantConfig::with(QuantMethod::Lnq, 2, 4))
        .unwrap();
    let ppl_lnq2 = p.perplexity(&p.apply_quantized(&ps, &lnq2), Split::Eval, "fwd_loss").unwrap();
    let ppl_gq2 = p.perplexity(&p.apply_quantized(&ps, &gq2), Split::Eval, "fwd_loss").unwrap();
    assert!(
        ppl_gq2 <= ppl_lnq2 * 1.10,
        "GuidedQuant hurt at 2 bits: lnq {ppl_lnq2} vs gq {ppl_gq2}"
    );
    // And both should sit between fp and untrained.
    assert!(ppl_lnq2 >= ppl_fp * 0.95);
    assert!(ppl_gq2 < ppl_untrained * 2.0);
}

#[test]
fn quantize_every_method_produces_finite_models() {
    let Some(p) = pipeline() else { return };
    let mut ps = p.init_params();
    p.train(&mut ps, 30, 0).unwrap();
    let stats = p.calib(&ps, true).unwrap();
    for method in [
        QuantMethod::Rtn,
        QuantMethod::Gptq,
        QuantMethod::SqueezeLlm,
        QuantMethod::Gptvq1d,
        QuantMethod::Gptvq2d,
        QuantMethod::Lnq,
        QuantMethod::Trellis,
    ] {
        let layers = p
            .quantize(&ps, &stats, &QuantConfig::with(method, 3, 2))
            .unwrap_or_else(|e| panic!("{method:?}: {e}"));
        assert_eq!(layers.len(), ps.cfg.linear_specs().len(), "{method:?}");
        for l in &layers {
            assert!(
                l.result.w_hat.data.iter().all(|v| v.is_finite()),
                "{method:?}/{} non-finite",
                l.name
            );
            assert!(l.result.avg_bits > 0.0);
        }
        let qps = p.apply_quantized(&ps, &layers);
        let ppl = p.perplexity(&qps, Split::Eval, "fwd_loss").unwrap();
        assert!(ppl.is_finite() && ppl > 1.0, "{method:?} ppl {ppl}");
    }
}

#[test]
fn sparse_fraction_reduces_two_bit_damage() {
    let Some(p) = pipeline() else { return };
    let mut ps = p.init_params();
    p.train(&mut ps, 60, 0).unwrap();
    let stats = p.calib(&ps, true).unwrap();
    let dense = p
        .quantize(&ps, &stats, &QuantConfig::with(QuantMethod::Gptq, 2, 0))
        .unwrap();
    let mut qcfg = QuantConfig::with(QuantMethod::Gptq, 2, 0);
    qcfg.sparse_frac = 0.01;
    let sparse = p.quantize(&ps, &stats, &qcfg).unwrap();
    let ppl_dense = p.perplexity(&p.apply_quantized(&ps, &dense), Split::Eval, "fwd_loss").unwrap();
    let ppl_sparse =
        p.perplexity(&p.apply_quantized(&ps, &sparse), Split::Eval, "fwd_loss").unwrap();
    assert!(
        ppl_sparse <= ppl_dense * 1.05,
        "sparse overlay hurt: {ppl_dense} -> {ppl_sparse}"
    );
}

#[test]
fn wa_quantization_path_matches_table5_shape() {
    // Rotation + GPTQ weights + activation fake-quant eval (Table 5 rig).
    let Some(p) = pipeline() else { return };
    let mut ps = p.init_params();
    p.train(&mut ps, 60, 0).unwrap();
    let toks = p.corpus.tokens(Split::Calib, 128);
    let mut rotated = ps.clone();
    let mut rng = guidedquant::util::Rng::new(0);
    guidedquant::quant::spinquant::spinquant_rotate(&mut rotated, &toks, 2, &mut rng);
    // Rotated fp model evaluates identically through the artifact.
    let ppl_plain = p.perplexity(&ps, Split::Eval, "fwd_loss").unwrap();
    let ppl_rot = p.perplexity(&rotated, Split::Eval, "fwd_loss").unwrap();
    assert!(
        (ppl_plain - ppl_rot).abs() / ppl_plain < 0.02,
        "rotation changed the function: {ppl_plain} vs {ppl_rot}"
    );
    // W4A4KV4 eval runs and degrades gracefully.
    let stats = p.calib(&rotated, true).unwrap();
    let layers = p
        .quantize(&rotated, &stats, &QuantConfig::with(QuantMethod::Gptq, 4, 2))
        .unwrap();
    let qps = p.apply_quantized(&rotated, &layers);
    let ppl_qa = p.perplexity(&qps, Split::Eval, "fwd_loss_qa4kv4").unwrap();
    assert!(ppl_qa.is_finite() && ppl_qa < ppl_plain * 2.0, "{ppl_qa}");
}
