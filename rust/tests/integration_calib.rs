//! Calibration integration: the calib_stats artifact's Hessians must agree
//! with independent reconstructions from the grad_taps artifact's raw
//! activations/gradients (Algorithm 1's math, checked end to end through
//! two different lowered graphs).

use guidedquant::cfg::preset;
use guidedquant::data::{Batcher, Corpus, CorpusConfig, Split};
use guidedquant::fisher::collect_stats;
use guidedquant::model::ParamStore;
use guidedquant::runtime::{Runtime, Value};
use guidedquant::tensor::Mat;
use guidedquant::util::Rng;

fn setup() -> Option<(Runtime, ParamStore, Corpus)> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let rt = Runtime::load(dir).unwrap();
    let (cfg, _) = preset("tiny");
    let ps = ParamStore::init(&cfg, &mut Rng::new(0));
    let corpus = Corpus::new(CorpusConfig::for_vocab(cfg.vocab, 0));
    Some((rt, ps, corpus))
}

#[test]
fn calib_stats_consistent_with_grad_taps() {
    let Some((rt, ps, corpus)) = setup() else { return };
    let bc = rt.manifest.batch;
    let groups = rt.manifest.groups;
    let mut batcher = Batcher::new(&corpus, Split::Calib, bc, 1);
    let toks = batcher.next_batch().unwrap();
    let mut args = rt.param_args(&ps);
    args.push(Value::tokens(bc.batch, bc.seq, &toks));

    let stats_out = rt.artifact("calib_stats").unwrap().execute(&args).unwrap();
    let taps_out = rt.artifact("grad_taps").unwrap().execute(&args).unwrap();
    // Same loss from both graphs.
    let l1 = stats_out[0].scalar_f32().unwrap();
    let l2 = taps_out[0].scalar_f32().unwrap();
    assert!((l1 - l2).abs() / l1.abs().max(1e-6) < 1e-4, "{l1} vs {l2}");

    let specs = ps.cfg.linear_specs();
    for li in [0usize, 3, 6] {
        let spec = &specs[li];
        let d = spec.d_in;
        let hs = stats_out[1 + 2 * li].as_f32().unwrap();
        let x = taps_out[1 + 2 * li].clone().into_mat().unwrap();
        let g = taps_out[2 + 2 * li].clone().into_mat().unwrap();
        // hs[0] == X^T X
        let want_h = guidedquant::tensor::ops::matmul_tn(&x, &x);
        guidedquant::testing::assert_close(&hs[..d * d], &want_h.data, 3e-2, 3e-2)
            .unwrap_or_else(|e| panic!("{}: H mismatch: {e}", spec.name));
        // hs[1] == X^T diag(s_1) X with s_1 = mean of first-group grads².
        let per = spec.d_out / groups;
        let mut xs = x.clone();
        for i in 0..x.rows {
            let mut s = 0.0f32;
            for j in 0..per {
                s += g.at(i, j) * g.at(i, j);
            }
            s /= per as f32;
            let sq = s.sqrt();
            for v in xs.row_mut(i) {
                *v *= sq;
            }
        }
        let want_h1 = guidedquant::tensor::ops::matmul_tn(&xs, &xs);
        let got = &hs[d * d..2 * d * d];
        // Relative tolerance scaled to the matrix magnitude.
        let scale = want_h1.max_abs().max(1e-12);
        for (a, b) in got.iter().zip(&want_h1.data) {
            assert!(
                (a - b).abs() < 3e-2 * scale,
                "{}: H̄_1 mismatch {a} vs {b} (scale {scale})",
                spec.name
            );
        }
        // diagf == (x²)^T (g²)
        let diagf = stats_out[2 + 2 * li].as_f32().unwrap();
        let mut want_df = Mat::zeros(spec.d_in, spec.d_out);
        for i in 0..x.rows {
            for a in 0..spec.d_in {
                let xa2 = x.at(i, a) * x.at(i, a);
                for b in 0..spec.d_out {
                    *want_df.at_mut(a, b) += xa2 * g.at(i, b) * g.at(i, b);
                }
            }
        }
        let dscale = want_df.max_abs().max(1e-12);
        for (a, b) in diagf.iter().zip(&want_df.data) {
            assert!((a - b).abs() < 3e-2 * dscale, "{}: diagF {a} vs {b}", spec.name);
        }
    }
}

#[test]
fn collect_stats_accumulates_batches() {
    let Some((rt, ps, corpus)) = setup() else { return };
    let bc = rt.manifest.batch;
    let mut b1 = Batcher::new(&corpus, Split::Calib, bc, 1);
    let s1 = collect_stats(&rt, &ps, &mut b1, 1).unwrap();
    let mut b2 = Batcher::new(&corpus, Split::Calib, bc, 2);
    let s2 = collect_stats(&rt, &ps, &mut b2, 2).unwrap();
    assert_eq!(s1.batches, 1);
    assert_eq!(s2.batches, 2);
    assert!(s2.tokens == 2 * s1.tokens);
    // Hessian sums should grow with more batches (PSD accumulations).
    let t1: f64 = s1.layers[0].hs[0].diag().iter().map(|&v| v as f64).sum();
    let t2: f64 = s2.layers[0].hs[0].diag().iter().map(|&v| v as f64).sum();
    assert!(t2 > t1, "trace did not grow: {t1} -> {t2}");
    // Hessians stay symmetric PSD-ish.
    let h = &s2.layers[0].hs[0];
    for i in 0..h.rows {
        for j in 0..h.cols {
            assert!((h.at(i, j) - h.at(j, i)).abs() < 1e-2 * h.max_abs());
        }
    }
}

#[test]
fn hessian_cache_round_trips_collected_stats() {
    let Some((rt, ps, corpus)) = setup() else { return };
    let bc = rt.manifest.batch;
    let mut batcher = Batcher::new(&corpus, Split::Calib, bc, 1);
    let stats = collect_stats(&rt, &ps, &mut batcher, 1).unwrap();
    let dir = std::env::temp_dir().join(format!("gq_it_cache_{}", std::process::id()));
    let cache = guidedquant::fisher::HessianCache::new(&dir);
    cache.save("tiny_it", &stats).unwrap();
    let back = cache.load("tiny_it").unwrap();
    assert_eq!(back.layers.len(), stats.layers.len());
    for (a, b) in back.layers.iter().zip(&stats.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.hs[0], b.hs[0]);
    }
    std::fs::remove_dir_all(dir).ok();
}
