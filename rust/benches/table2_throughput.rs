//! Paper Table 2 (+7, +11) — end-to-end decode throughput by serving
//! format and bit-width. Reproduction target: uniform ≈ non-uniform scalar,
//! both faster than vector/trellis (decode overhead), all faster than fp32
//! at low bits on the memory-bound decode path.

#[path = "common.rs"]
mod common;

use guidedquant::report::{f, Table};
use guidedquant::serve::{build_serving_model, generate_batch, ServeFormat};
use guidedquant::util::human_bytes;
use guidedquant::util::Rng;

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let fast = guidedquant::bench::fast_mode();
    let (requests, gen_tokens, prompt_len) = if fast { (2, 8, 4) } else { (4, 48, 16) };
    let workers = s.pipeline.cfg.workers;

    let mut table = Table::new(
        &format!("Table 2 analog — decode throughput ({model}, {requests} reqs × {gen_tokens} tokens)"),
        &["format", "bits", "tok/s", "p50_ms", "p99_ms", "weights"],
    );

    let mut rng = Rng::new(11);
    let vocab = s.ps.cfg.vocab;
    let prompts: Vec<Vec<u32>> = (0..requests)
        .map(|_| (0..prompt_len).map(|_| rng.below(vocab) as u32).collect())
        .collect();

    let mut run = |format: ServeFormat, bits: u32| {
        let m = build_serving_model(&s.ps, Some(&s.stats), format, bits).unwrap();
        // Warm once, then measure.
        let _ = generate_batch(&m, &prompts[..1.min(prompts.len())], 2, workers);
        let (_, stats) = generate_batch(&m, &prompts, gen_tokens, workers);
        table.row(vec![
            format.name().into(),
            if format == ServeFormat::Fp32 { "32".into() } else { bits.to_string() },
            f(stats.tok_per_sec, 1),
            f(stats.p50_ms, 3),
            f(stats.p99_ms, 3),
            human_bytes(stats.weight_bytes as u64),
        ]);
    };

    run(ServeFormat::Fp32, 16);
    for bits in [2u32, 3, 4] {
        run(ServeFormat::UniformScalar, bits);
        run(ServeFormat::NonUniformScalar, bits);
        run(ServeFormat::Vector, bits);
        run(ServeFormat::Trellis, bits);
    }
    table.print();
    table.save_csv("table2_throughput").unwrap();
}
