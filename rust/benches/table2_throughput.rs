//! Paper Table 2 (+7, +11) — end-to-end decode throughput by serving
//! format, bit-width, and batch size. Reproduction target: uniform ≈
//! non-uniform scalar, both faster than vector/trellis (decode overhead),
//! all faster than fp32 at low bits on the memory-bound decode path — and,
//! with the continuous-batching scheduler, every quantized format gains
//! over the thread-per-sequence baseline as the batch grows, because each
//! weight tile is decoded once per step instead of once per lane.
//!
//! Throughput does not depend on weight values, so this bench runs from
//! randomly initialized parameters and needs no AOT artifacts.

use guidedquant::cfg::{preset, ServeConfig};
use guidedquant::model::ParamStore;
use guidedquant::report::{f, Table};
use guidedquant::serve::{
    build_serving_model, generate_per_sequence, generate_scheduled, random_prompts, ServeFormat,
};
use guidedquant::util::{human_bytes, Rng};

fn main() {
    // Table 2 numbers depend on which batched decode kernel ran — record it.
    println!(
        "batched decode kernel: {}",
        guidedquant::tensor::gemm::kernel_desc()
    );
    let model = std::env::var("GQ_BENCH_MODEL").unwrap_or_else(|_| "tiny".to_string());
    let (cfg, _) = preset(&model);
    let ps = ParamStore::init(&cfg, &mut Rng::new(0));
    let fast = guidedquant::bench::fast_mode();
    let (gen_tokens, prompt_len) = if fast { (8, 4) } else { (32, 16) };
    let batches: &[usize] = if fast { &[1, 4] } else { &[1, 4, 8, 16] };
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mut table = Table::new(
        &format!("Table 2 analog — decode throughput ({model}, {gen_tokens} tok/req, batch sweep)"),
        &["format", "bits", "batch", "mode", "tok/s", "p50_ms", "ttft_p50", "occupancy", "weights"],
    );

    let mut run = |format: ServeFormat, bits: u32| {
        let m = build_serving_model(&ps, None, format, bits).unwrap();
        let warm = random_prompts(cfg.vocab, 1, prompt_len, 7);
        let _ = generate_per_sequence(&m, &warm, 2, workers).unwrap();
        for &batch in batches {
            let prompts = random_prompts(cfg.vocab, batch, prompt_len, 11 + batch as u64);
            let bits_str =
                if format == ServeFormat::Fp32 { "32".to_string() } else { bits.to_string() };
            let (_, seq) = generate_per_sequence(&m, &prompts, gen_tokens, workers).unwrap();
            table.row(vec![
                format.name().into(),
                bits_str.clone(),
                batch.to_string(),
                "per-seq".into(),
                f(seq.tok_per_sec, 1),
                f(seq.p50_ms, 3),
                f(seq.ttft_p50_ms, 3),
                f(1.0, 2),
                human_bytes(seq.weight_bytes as u64),
            ]);
            let scfg = ServeConfig { max_batch: batch, max_queued: batch, ..ServeConfig::default() };
            let (_, sch) = generate_scheduled(&m, &prompts, gen_tokens, workers, scfg).unwrap();
            table.row(vec![
                format.name().into(),
                bits_str,
                batch.to_string(),
                "scheduler".into(),
                f(sch.tok_per_sec, 1),
                f(sch.p50_ms, 3),
                f(sch.ttft_p50_ms, 3),
                f(sch.batch_occupancy, 2),
                human_bytes(sch.weight_bytes as u64),
            ]);
        }
    };

    run(ServeFormat::Fp32, 16);
    for bits in [2u32, 3, 4] {
        run(ServeFormat::UniformScalar, bits);
        run(ServeFormat::NonUniformScalar, bits);
        run(ServeFormat::Vector, bits);
        run(ServeFormat::Trellis, bits);
    }
    table.print();
    table.save_csv("table2_throughput").unwrap();
}
