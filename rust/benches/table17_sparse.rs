//! Paper Table 17 — dense-and-sparse decomposition: keep 0.45% of weights
//! in full precision. Rows: SqueezeLLM / LNQ / LNQ+GQ, all with the same
//! sparse overlay fraction, at 2/3/4 bits.

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::report::{f, Table};

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let frac = 0.0045f32;
    let mut table = Table::new(
        &format!("Table 17 analog — dense-and-sparse ({model}, {:.2}% fp)", frac * 100.0),
        &["method", "bits", "sparse", "avg_bits", "ppl_eval"],
    );
    for bits in [2u32, 3, 4] {
        for (name, method, groups) in
            [("lnq", QuantMethod::Lnq, 0usize), ("lnq+gquant", QuantMethod::Lnq, 4)]
        {
            for sparse in [0.0f32, frac] {
                let mut qcfg = QuantConfig::with(method, bits, groups);
                qcfg.sparse_frac = sparse;
                let layers = s.pipeline.quantize(&s.ps, &s.stats, &qcfg).unwrap();
                let qps = s.apply(&layers);
                table.row(vec![
                    name.into(),
                    bits.to_string(),
                    if sparse > 0.0 { "0.45%".into() } else { "-".to_string() },
                    f(s.pipeline.avg_bits(&layers), 2),
                    f(s.ppl(&qps, "fwd_loss"), 3),
                ]);
            }
        }
    }
    table.print();
    table.save_csv("table17_sparse").unwrap();
}
