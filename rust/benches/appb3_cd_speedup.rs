//! Paper Appendix B.3 — the CD implementation speedup ladder:
//! exhaustive → closed-form → precompute (Alg 3) → lazy batch (Alg 4).
//! The paper reports 3.9h → 2.7h → 1.2h → 0.9h on Llama-2-7B/GPU; the
//! reproduction target is the monotone speedup shape with identical codes.

use guidedquant::bench::bench;
use guidedquant::quant::cd::{cd_inplace, CdConfig, CdStrategy};
use guidedquant::quant::grid::{round_all, UniformGrid};
use guidedquant::report::{f, Table};
use guidedquant::tensor::ops::matmul_tn;
use guidedquant::tensor::Mat;
use guidedquant::util::Rng;

fn main() {
    let fast = guidedquant::bench::fast_mode();
    let (d_in, d_out) = if fast { (64, 64) } else { (256, 256) };
    let mut rng = Rng::new(0);
    let x = Mat::randn(2 * d_in, d_in, 1.0, &mut rng);
    let h = matmul_tn(&x, &x);
    let w = Mat::randn(d_in, d_out, 1.0, &mut rng);
    let grid = UniformGrid::fit(&w, 2);

    let mut table = Table::new(
        &format!("Appendix B.3 analog — CD strategy ladder ({d_in}x{d_out}, 2 cycles)"),
        &["strategy", "ms", "speedup_vs_exhaustive"],
    );
    let mut reference: Option<(f64, Vec<u16>)> = None;
    for (name, strategy, reps) in [
        ("exhaustive", CdStrategy::Exhaustive, 1usize),
        ("closed-form", CdStrategy::ClosedForm, 2),
        ("precompute (Alg 3)", CdStrategy::Precompute, 5),
        ("lazy batch (Alg 4)", CdStrategy::Lazy { block: 32 }, 5),
    ] {
        let run = || {
            let (mut w_hat, mut codes) = round_all(&w, &grid);
            cd_inplace(&h, &w, &mut w_hat, &mut codes, &grid, CdConfig { cycles: 2, strategy });
            codes
        };
        let codes = run();
        let r = bench(name, 0, reps, run);
        match &reference {
            None => reference = Some((r.mean_secs, codes)),
            Some((base, base_codes)) => {
                assert_eq!(&codes, base_codes, "{name} diverged from exhaustive");
                table.row(vec![
                    name.into(),
                    f(r.mean_secs * 1e3, 1),
                    f(base / r.mean_secs, 2),
                ]);
                continue;
            }
        }
        table.row(vec![name.into(), f(r.mean_secs * 1e3, 1), "1.00".into()]);
    }
    table.print();
    table.save_csv("appb3_cd_speedup").unwrap();
}
