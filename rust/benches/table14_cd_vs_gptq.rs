//! Paper Table 14 — ablation on the assignment optimizer inside
//! LNQ (+ GuidedQuant): cyclic CD (the paper's choice) vs GPTQ. Both share
//! the exact closed-form codebook update; only the P-step differs.

#[path = "common.rs"]
mod common;

use anyhow::Result;
use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::quant::gptq::gptq_with_grid;
use guidedquant::quant::grid::{avg_bits_scalar, LutGrid};
use guidedquant::quant::guided::guided_quantize;
use guidedquant::quant::lnq::{codebook_ls_update, decode, init_codebooks};
use guidedquant::quant::{LayerQuantizer, QuantResult};
use guidedquant::report::{f, Table};
use guidedquant::tensor::Mat;
use guidedquant::util::Rng;

/// LNQ with GPTQ-based assignment updates (the Table 14 alternative).
struct LnqGptqAssign {
    bits: u32,
    t_iters: usize,
}

impl LayerQuantizer for LnqGptqAssign {
    fn quantize(&self, h: &Mat, w: &Mat) -> Result<QuantResult> {
        let m = 1usize << self.bits;
        let mut rng = Rng::new(0x147147);
        let diag = h.diag();
        let (mut cbs, mut codes) =
            init_codebooks(w, |_| diag.iter().map(|&v| v.max(1e-12)).collect(), m, &mut rng);
        for _ in 0..self.t_iters {
            codebook_ls_update(h, w, &codes, &mut cbs)?;
            let grid = LutGrid::new(cbs.clone());
            let (_, new_codes) = gptq_with_grid(h, w, &grid, 32)?;
            codes = new_codes;
        }
        codebook_ls_update(h, w, &codes, &mut cbs)?;
        let w_hat = decode(&codes, &cbs, w.rows);
        Ok(QuantResult {
            w_hat,
            codes: Some(codes),
            codebooks: Some(cbs),
            avg_bits: avg_bits_scalar(w.rows, w.cols, self.bits),
        })
    }

    fn name(&self) -> &'static str {
        "lnq-gptq-assign"
    }
}

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let mut table = Table::new(
        &format!("Table 14 analog — P-step optimizer inside LNQ+GQ ({model})"),
        &["bits", "optimizer", "ppl_eval", "ppl_shift"],
    );
    for bits in [2u32, 3, 4] {
        // CD variant (the shipped LNQ): via the standard pipeline.
        let layers = s
            .pipeline
            .quantize(&s.ps, &s.stats, &QuantConfig::with(QuantMethod::Lnq, bits, 4))
            .unwrap();
        let qps = s.apply(&layers);
        table.row(vec![
            bits.to_string(),
            "coordinate descent".into(),
            f(s.ppl(&qps, "fwd_loss"), 3),
            f(s.ppl_shift(&qps), 3),
        ]);

        // GPTQ-assignment variant, guided with the same Hessians.
        let q = LnqGptqAssign { bits, t_iters: 2 };
        let mut qps2 = s.ps.clone();
        for spec in s.ps.cfg.linear_specs() {
            let ls = s.stats.layer(&spec.name).unwrap();
            let hessians = ls.guided_hessians(4.min(s.stats.groups));
            let res = guided_quantize(&q, &hessians, s.ps.get(&spec.name)).unwrap();
            qps2.set(&spec.name, res.w_hat);
        }
        table.row(vec![
            bits.to_string(),
            "gptq".into(),
            f(s.ppl(&qps2, "fwd_loss"), 3),
            f(s.ppl_shift(&qps2), 3),
        ]);
    }
    table.print();
    table.save_csv("table14_cd_vs_gptq").unwrap();
}
