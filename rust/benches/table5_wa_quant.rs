//! Paper Table 5 (+ Table 16) — weight-and-activation quantization.
//!
//! Rows: QuaRot-like (plain Hadamard rotation), SpinQuant-like (searched
//! rotation), each ± GuidedQuant on the GPTQ W-step; settings W4A4KV4,
//! W4A4KV16 (Table 5) and W2/W3 A4KV4 (Table 16). All evaluated through
//! the fwd_loss_qa* artifacts (activations + KV fake-quant in-graph).

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::data::{Batcher, Split};
use guidedquant::fisher::collect_stats;
use guidedquant::quant::spinquant::spinquant_rotate;
use guidedquant::report::{f, Table};
use guidedquant::util::Rng;

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let corpus = &s.pipeline.corpus;
    let sample_tokens = corpus.tokens(Split::Calib, 192);

    let fp16 = s.ppl(&s.ps, "fwd_loss");
    let mut table = Table::new(
        &format!("Table 5/16 analog — W&A quantization ({model}); fp ppl {fp16:.3}"),
        &["method", "setting", "ppl_qa"],
    );

    // Two rotation flavors: QuaRot (plain Hadamard, 1 candidate) vs
    // SpinQuant-lite (best of 6 candidates by outlier score).
    for (flavor, candidates) in [("quarot", 1usize), ("spinquant", 6)] {
        let mut rotated = s.ps.clone();
        let mut rng = Rng::new(42);
        let (_r, before, after) =
            spinquant_rotate(&mut rotated, &sample_tokens, candidates, &mut rng);
        eprintln!("[{flavor}] outlier score {before:.2} -> {after:.2}");
        // Hessians must come from the rotated model.
        let mut batcher = Batcher::new(corpus, Split::Calib, s.pipeline.rt.manifest.batch, 4);
        let stats = collect_stats(&s.pipeline.rt, &rotated, &mut batcher, 4).unwrap();
        for (wbits, artifact, setting) in [
            (4u32, "fwd_loss_qa4kv4", "W4A4KV4"),
            (4, "fwd_loss_qa4kv16", "W4A4KV16"),
            (3, "fwd_loss_qa4kv4", "W3A4KV4"),
            (2, "fwd_loss_qa4kv4", "W2A4KV4"),
        ] {
            for (suffix, groups) in [("", 0usize), ("+gquant", 4)] {
                let qcfg = QuantConfig::with(QuantMethod::Gptq, wbits, groups);
                let layers = s.pipeline.quantize(&rotated, &stats, &qcfg).unwrap();
                let qps = s.pipeline.apply_quantized(&rotated, &layers);
                let ppl = s.ppl(&qps, artifact);
                table.row(vec![format!("{flavor}{suffix}"), setting.into(), f(ppl, 3)]);
            }
        }
    }
    table.print();
    table.save_csv("table5_wa_quant").unwrap();
}
