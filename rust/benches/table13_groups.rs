//! Paper Table 13 — the number-of-groups g ablation: g = 0 means the plain
//! layer-wise objective; g ∈ {1, 2, 4} are GuidedQuant variants (the
//! artifact caches g = 4; smaller g re-average the cached blocks).

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::report::{f, Table};

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let mut table = Table::new(
        &format!("Table 13 analog — group-count ablation ({model})"),
        &["bits", "g", "ppl_eval", "ppl_shift"],
    );
    for bits in [2u32, 3] {
        for g in [0usize, 1, 2, 4] {
            let layers = s
                .pipeline
                .quantize(&s.ps, &s.stats, &QuantConfig::with(QuantMethod::Lnq, bits, g))
                .unwrap();
            let qps = s.apply(&layers);
            let label = if g == 0 { "- (layer-wise)".to_string() } else { g.to_string() };
            table.row(vec![
                bits.to_string(),
                label,
                f(s.ppl(&qps, "fwd_loss"), 3),
                f(s.ppl_shift(&qps), 3),
            ]);
        }
    }
    table.print();
    table.save_csv("table13_groups").unwrap();
}
