//! Paper Figures 3 & 4 + Appendix D.11 — Fisher-structure analysis.
//!
//! Pulls raw activations X and end-loss output gradients G from the
//! grad_taps artifact, builds the exact two-channel Fisher submatrix per
//! linear, and compares the WoodFisher-style B×B block-diagonal cut against
//! the GuidedQuant group-average at equal storage. Prints, per layer:
//! the within-channel block mass fraction (the "prominent block-diagonal
//! structure") and both approximation errors.

#[path = "common.rs"]
mod common;

use guidedquant::data::{Batcher, Split};
use guidedquant::fisher::structure::{
    block_diag_approx, block_mass_fraction, guided_approx_two_channel, rel_error,
    two_channel_fisher,
};
use guidedquant::report::{f, Table};
use guidedquant::runtime::Value;

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let rt = &s.pipeline.rt;
    let bc = rt.manifest.batch;
    let artifact = rt.artifact("grad_taps").unwrap();
    let mut batcher = Batcher::new(&s.pipeline.corpus, Split::Calib, bc, 1);
    let toks = batcher.next_batch().unwrap();
    let mut args = rt.param_args(&s.ps);
    args.push(Value::tokens(bc.batch, bc.seq, &toks));
    let outs = artifact.execute(&args).unwrap();

    let specs = s.ps.cfg.linear_specs();
    let mut table = Table::new(
        &format!("Figures 3/4 analog — Fisher structure ({model}, first block)"),
        &["layer", "block_mass", "err_woodfisher", "err_guidedquant"],
    );
    // First transformer block's 7 linears (as in the paper's figures).
    for (li, spec) in specs.iter().take(7).enumerate() {
        let x = outs[1 + 2 * li].clone().into_mat().unwrap();
        let g = outs[2 + 2 * li].clone().into_mat().unwrap();
        let fisher = two_channel_fisher(&x, &g, 0, 1);
        let d = spec.d_in;
        // Equal storage: guided stores one d×d shared block; WoodFisher gets
        // B = d/2 so 4 blocks of (d/2)² = d² entries too.
        let wf = block_diag_approx(&fisher, d / 2);
        let gq = guided_approx_two_channel(&fisher);
        table.row(vec![
            spec.name.clone(),
            f(block_mass_fraction(&fisher, d), 3),
            f(rel_error(&fisher, &wf), 4),
            f(rel_error(&fisher, &gq), 4),
        ]);
    }
    table.print();
    table.save_csv("fig34_fisher").unwrap();
}
