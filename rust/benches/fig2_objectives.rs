//! Paper Figure 2 — non-uniform scalar quantization under three objectives:
//! layer-wise output error (LNQ), weighted k-means (SqueezeLLM), and the
//! approximated GuidedQuant objective (LNQ + GQ), across bit-widths.

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::report::{f, Table};

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let fp = s.ppl(&s.ps, "fwd_loss");
    let mut table = Table::new(
        &format!("Figure 2 analog — objective comparison ({model}); fp32 ppl {fp:.3}"),
        &["bits", "weighted_kmeans(SqLLM)", "layer_wise(LNQ)", "guidedquant(LNQ+GQ)"],
    );
    for bits in [2u32, 3, 4] {
        let ppl_of = |method: QuantMethod, groups: usize| -> f64 {
            let layers = s
                .pipeline
                .quantize(&s.ps, &s.stats, &QuantConfig::with(method, bits, groups))
                .unwrap();
            s.ppl(&s.apply(&layers), "fwd_loss")
        };
        table.row(vec![
            bits.to_string(),
            f(ppl_of(QuantMethod::SqueezeLlm, 0), 3),
            f(ppl_of(QuantMethod::Lnq, 0), 3),
            f(ppl_of(QuantMethod::Lnq, 4), 3),
        ]);
    }
    table.print();
    table.save_csv("fig2_objectives").unwrap();
}
