//! Paper Table 3 — weight-only scalar PTQ without end-to-end fine-tuning.
//!
//! Rows: GPTQ (uniform), SqueezeLLM, GPTVQ 1D, LNQ, LNQ + GuidedQuant;
//! columns: bits ∈ {2, 3, 4} × {eval (Wiki2 analog), shift (C4 analog)}.
//! The reproduction target is the *ordering* (LNQ+GQ ≤ LNQ ≤ GPTVQ1D /
//! SqueezeLLM, with the largest wins at 2 bits), not absolute perplexity.
//! Table 10 (Llama-3 analog) is this bench with GQ_BENCH_MODEL=base.

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::report::{f, Table};

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let fp = s.ppl(&s.ps, "fwd_loss");
    let fp_shift = s.ppl_shift(&s.ps);

    let mut table = Table::new(
        &format!("Table 3 analog — weight-only scalar PTQ ({model}); fp32 ppl {fp:.3}/{fp_shift:.3}"),
        &["method", "bits", "avg_bits", "ppl_eval", "ppl_shift"],
    );
    for bits in [2u32, 3, 4] {
        let rows: Vec<(&str, QuantConfig)> = vec![
            ("gptq", QuantConfig::with(QuantMethod::Gptq, bits, 0)),
            ("squeezellm", QuantConfig::with(QuantMethod::SqueezeLlm, bits, 0)),
            ("gptvq1d", QuantConfig::with(QuantMethod::Gptvq1d, bits, 0)),
            ("lnq", QuantConfig::with(QuantMethod::Lnq, bits, 0)),
            ("lnq+gquant", QuantConfig::with(QuantMethod::Lnq, bits, 4)),
        ];
        for (name, qcfg) in rows {
            let layers = s.pipeline.quantize(&s.ps, &s.stats, &qcfg).unwrap();
            let qps = s.apply(&layers);
            let ppl = s.ppl(&qps, "fwd_loss");
            let shift = s.ppl_shift(&qps);
            let avg_bits = s.pipeline.avg_bits(&layers);
            table.row(vec![
                name.into(),
                bits.to_string(),
                f(avg_bits, 2),
                f(ppl, 3),
                f(shift, 3),
            ]);
        }
    }
    table.print();
    table.save_csv("table3_scalar_ptq").unwrap();
}
