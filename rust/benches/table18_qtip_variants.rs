//! Paper Table 18 — trellis generator variants (1MAD / 3INST / HYB),
//! each with and without GuidedQuant, at 2/3/4 bits.

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod, TrellisVariant};
use guidedquant::report::{f, Table};

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let fp = s.ppl(&s.ps, "fwd_loss");
    let mut table = Table::new(
        &format!("Table 18 analog — QTIP variants ({model}); fp32 ppl {fp:.3}"),
        &["variant", "method", "bits", "ppl_eval"],
    );
    for variant in [TrellisVariant::OneMad, TrellisVariant::ThreeInst, TrellisVariant::Hyb] {
        for bits in [2u32, 3, 4] {
            for (suffix, groups) in [("qtip", 0usize), ("qtip+gq", 4)] {
                let mut qcfg = QuantConfig::with(QuantMethod::Trellis, bits, groups);
                qcfg.trellis_variant = variant;
                let layers = s.pipeline.quantize(&s.ps, &s.stats, &qcfg).unwrap();
                let qps = s.apply(&layers);
                table.row(vec![
                    variant.name().into(),
                    suffix.into(),
                    bits.to_string(),
                    f(s.ppl(&qps, "fwd_loss"), 3),
                ]);
            }
        }
    }
    table.print();
    table.save_csv("table18_qtip_variants").unwrap();
}
