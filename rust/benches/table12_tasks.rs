//! Paper Table 12 — downstream-task accuracy of quantized models
//! (zero-shot average + few-shot analog): next-token accuracy and
//! multiple-choice accuracy on the synthetic language.

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::data::Split;
use guidedquant::eval::{multiple_choice_accuracy, next_token_accuracy};
use guidedquant::model::NativeModel;
use guidedquant::report::{f, Table};

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let corpus = &s.pipeline.corpus;
    let fast = guidedquant::bench::fast_mode();
    let (nt_n, mc_n) = if fast { (40, 12) } else { (160, 48) };

    let mut table = Table::new(
        &format!("Table 12 analog — downstream tasks ({model})"),
        &["method", "bits", "next_token_acc", "multi_choice_acc"],
    );
    let mut eval_row = |name: &str, ps: &guidedquant::model::ParamStore, bits: &str| {
        let m = NativeModel::from_params(ps);
        let nt = next_token_accuracy(&m, corpus, Split::Eval, nt_n);
        let mc = multiple_choice_accuracy(&m, corpus, Split::Eval, mc_n, 4, 9);
        table.row(vec![name.into(), bits.into(), f(nt, 3), f(mc, 3)]);
    };
    eval_row("original", &s.ps, "32");
    for bits in [2u32, 3] {
        for (name, method, groups) in [
            ("squeezellm", QuantMethod::SqueezeLlm, 0usize),
            ("gptvq1d", QuantMethod::Gptvq1d, 0),
            ("lnq", QuantMethod::Lnq, 0),
            ("lnq+gquant", QuantMethod::Lnq, 4),
        ] {
            let layers = s
                .pipeline
                .quantize(&s.ps, &s.stats, &QuantConfig::with(method, bits, groups))
                .unwrap();
            let qps = s.apply(&layers);
            eval_row(name, &qps, &bits.to_string());
        }
    }
    table.print();
    table.save_csv("table12_tasks").unwrap();
}
