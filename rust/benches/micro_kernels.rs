//! Micro-benchmarks of the hot paths: serving-format matvec kernels
//! (the Table 2 inner loop), the native matmul, serial-vs-pool rows for
//! the parallel kernels (tiled `matmul_tn`, the column-sharded batched
//! decode step, and batch-8 long-context paged attention), cold-prefill
//! vs prefix-hit prefill through the scheduler's shared-prefix KV index,
//! and the L1
//! xtsx Pallas kernel executed through its demo artifact vs a native Rust
//! reduction (skipped when no AOT artifacts are present, so CI smoke runs
//! work from a bare checkout).
//!
//! When `GQ_BENCH_JSON=<path>` is set, every speedup comparison is also
//! written to `<path>` as machine-readable JSON (one row per
//! kernel/format/batch with baseline ms, candidate ms, and the speedup
//! factor). CI's micro-kernel smoke uploads this as the
//! `BENCH_micro_kernels.json` artifact so measured numbers can be recorded
//! in the ROADMAP from any CI run.

#[path = "common.rs"]
mod common;

use guidedquant::bench::bench;
use guidedquant::cfg::{preset, KvDtype, ServeConfig, TrellisVariant};
use guidedquant::model::attention::attention_batch_with;
use guidedquant::model::forward::{matmul_col_sharded_with, LinearOp};
use guidedquant::model::{DecodeState, NativeModel, ParamStore};
use guidedquant::quant::formats::{
    AnyPrecisionLinear, LutLinear, TrellisLinear, UniformScalarLinear, VqLinear,
};
use guidedquant::quant::grid::{round_all, rtn_quantize, UniformGrid};
use guidedquant::quant::trellis::{Generator, Trellis, TrellisCode};
use guidedquant::runtime::Value;
use guidedquant::serve::{random_prompts, Scheduler};
use guidedquant::tensor::gemm::{self, ColWindow};
use guidedquant::tensor::ops::{matmul, matmul_tn, matmul_tn_with, num_threads};
use guidedquant::tensor::simd;
use guidedquant::tensor::Mat;
use guidedquant::util::json::Json;
use guidedquant::util::Rng;

/// One speedup comparison as a JSON row (times in milliseconds).
fn speedup_row(kernel: &str, baseline_ms: f64, candidate_ms: f64) -> Json {
    Json::object()
        .with("kernel", kernel)
        .with("baseline_ms", baseline_ms)
        .with("candidate_ms", candidate_ms)
        .with("speedup", baseline_ms / candidate_ms.max(1e-9))
}

/// Dump the collected speedup rows when `GQ_BENCH_JSON=<path>` is set.
fn write_bench_json(rows: &[Json], fast: bool, threads: usize, dim: usize) {
    let Some(path) = std::env::var_os("GQ_BENCH_JSON") else { return };
    let path = std::path::PathBuf::from(path);
    let doc = Json::object()
        .with("bench", "micro_kernels")
        .with("fast_mode", fast)
        .with("threads", threads)
        .with("dim", dim)
        .with("rows", rows.to_vec());
    match std::fs::write(&path, doc.encode() + "\n") {
        Ok(()) => println!("wrote {} speedup rows to {}", rows.len(), path.display()),
        Err(e) => {
            eprintln!("failed to write {}: {e}", path.display());
            std::process::exit(1);
        }
    }
}

fn main() {
    let fast = guidedquant::bench::fast_mode();
    println!("batched decode kernel: {}", gemm::kernel_desc());
    let d = if fast { 128 } else { 512 };
    let mut rng = Rng::new(0);
    let w = Mat::randn(d, d, 1.0, &mut rng);
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let mut out = vec![0.0f32; d];

    println!("-- serving matvec kernels ({d}x{d}) --");
    let reps = if fast { 20 } else { 200 };
    bench("matvec fp32", 3, reps, || w.matvec(&x, &mut out));
    let grid = UniformGrid::fit(&w, 4);
    let (_, codes) = round_all(&w, &grid);
    let uni = UniformScalarLinear::new(&codes, &grid, d, d);
    bench("matvec uniform-4bit", 3, reps, || uni.matvec(&x, &mut out));
    let res = rtn_quantize(&w, 4);
    let (lut_codes, lut_cb) = (res.codes.unwrap(), res.codebooks.unwrap());
    let lut = LutLinear::new(&lut_codes, lut_cb.clone(), 4, d, d);
    bench("matvec lut-4bit", 3, reps, || lut.matvec(&x, &mut out));

    println!("-- matmul --");
    let a = Mat::randn(d, d, 1.0, &mut rng);
    let b = Mat::randn(d, d, 1.0, &mut rng);
    let r = bench("matmul dxd", 1, if fast { 3 } else { 10 }, || matmul(&a, &b));
    let flops = 2.0 * (d as f64).powi(3);
    println!("   ≈ {:.2} GFLOP/s", flops / r.mean_secs / 1e9);

    // -- quantized GEMM: row-at-a-time vs tiled dequant-once kernels ------
    // Every serving format, batch 1 and 8, bit-identical by contract —
    // only the decode/apply schedule differs. VQ and trellis operands are
    // built directly from random codes (throughput does not depend on
    // weight values, and running the quantizers here would dwarf the
    // kernels being measured).
    println!("-- quantized GEMM: row-at-a-time vs tiled ({d}x{d}) --");
    let (vdim, vbits) = (4usize, 6u32);
    let kcent = 1usize << vbits;
    let vq_cb = Mat::randn(d, kcent * vdim, 1.0, &mut rng);
    let vq_codes: Vec<u16> = (0..(d / vdim) * d).map(|_| rng.below(kcent) as u16).collect();
    let vq = VqLinear::new(&vq_codes, vq_cb, vdim, vbits, d, d);
    let tcfg = Trellis::new(2, TrellisVariant::ThreeInst);
    let tgen = Generator::new(TrellisVariant::ThreeInst, tcfg.state_bits, &[], &mut rng);
    let tcodes: Vec<TrellisCode> = (0..d)
        .map(|_| TrellisCode {
            initial_state: rng.below(tcfg.n_states()) as u32,
            symbols: (0..d).map(|_| rng.below(1usize << tcfg.bits) as u16).collect(),
            scale: 0.5 + rng.f32(),
        })
        .collect();
    let trellis = TrellisLinear::new(&tcodes, tgen, tcfg, d);
    let gemm_reps = |batch: usize| {
        if fast {
            5
        } else if batch == 1 {
            60
        } else {
            20
        }
    };
    let mut rows: Vec<Json> = Vec::new();
    for (name, lin) in [
        ("fp32", &w as &dyn LinearOp),
        ("uniform-4bit", &uni),
        ("lut-4bit", &lut),
        ("vq-6bit/d4", &vq),
        ("trellis-2bit", &trellis),
    ] {
        for batch in [1usize, 8] {
            let xs = Mat::randn(batch, d, 1.0, &mut rng);
            let mut outm = Mat::zeros(batch, d);
            let reps = gemm_reps(batch);
            let s = bench(&format!("{name} b={batch} row-at-a-time"), 1, reps, || {
                lin.matmul_cols(&xs, &mut ColWindow::full(&mut outm))
            });
            let t = bench(&format!("{name} b={batch} tiled"), 1, reps, || {
                gemm::matmul_tiled_with(lin, &xs, &mut ColWindow::full(&mut outm), gemm::TILE_ROWS)
            });
            println!(
                "   {name} b={batch} tiled speedup ×{:.2}",
                s.mean_secs / t.mean_secs.max(1e-12)
            );
            rows.push(
                speedup_row("tiled_gemm", s.mean_secs * 1e3, t.mean_secs * 1e3)
                    .with("format", name)
                    .with("batch", batch),
            );
        }
    }

    // -- SIMD micro-kernels: forced-scalar vs dispatched vector paths -----
    // Same tiled dequant-once engine either way; only the inner-loop
    // instruction level changes. The two runs are bit-identical by the
    // simd contract, so the ratio is pure ALU/bandwidth.
    println!("-- tiled GEMM: forced scalar vs {} --", simd::desc());
    for (name, lin) in [
        ("fp32", &w as &dyn LinearOp),
        ("uniform-4bit", &uni),
        ("lut-4bit", &lut),
        ("vq-6bit/d4", &vq),
        ("trellis-2bit", &trellis),
    ] {
        for batch in [1usize, 8] {
            let xs = Mat::randn(batch, d, 1.0, &mut rng);
            let mut outm = Mat::zeros(batch, d);
            let reps = gemm_reps(batch);
            simd::force(Some(false));
            let s = bench(&format!("{name} b={batch} tiled scalar"), 1, reps, || {
                gemm::matmul_tiled_with(lin, &xs, &mut ColWindow::full(&mut outm), gemm::TILE_ROWS)
            });
            simd::force(Some(true));
            let v = bench(&format!("{name} b={batch} tiled simd"), 1, reps, || {
                gemm::matmul_tiled_with(lin, &xs, &mut ColWindow::full(&mut outm), gemm::TILE_ROWS)
            });
            simd::force(None);
            println!(
                "   {name} b={batch} simd speedup ×{:.2}",
                s.mean_secs / v.mean_secs.max(1e-12)
            );
            rows.push(
                speedup_row("simd_gemm", s.mean_secs * 1e3, v.mean_secs * 1e3)
                    .with("format", name)
                    .with("batch", batch),
            );
        }
    }

    // -- any-precision: plane-prefix decode vs the dedicated 4-bit LUT ----
    // One bit-plane artifact serves every precision; a view at p bits
    // gathers only the top p planes before the shared LUT lookup. The
    // baseline is the dedicated LutLinear at 4 bits built from the SAME
    // rtn codes: the 4-bit row measures pure plane-gather overhead (the
    // outputs are bit-identical by contract), while the 2/3-bit rows show
    // the decode work a downshifted request skips. Ungated: the ratio
    // tracks plane count and tile residency, not a fixed floor.
    println!("-- any-precision plane-prefix decode ({d}x{d}) --");
    let ap4 = AnyPrecisionLinear::new(&lut_codes, lut_cb.clone(), 4, d, d);
    let art = ap4.artifact().clone();
    for prec in [2u32, 3, 4] {
        let ap = AnyPrecisionLinear::from_artifact(art.clone(), prec);
        for batch in [1usize, 8] {
            let xs = Mat::randn(batch, d, 1.0, &mut rng);
            let mut outm = Mat::zeros(batch, d);
            let reps = gemm_reps(batch);
            let s = bench(&format!("lut-4bit b={batch} tiled"), 1, reps, || {
                gemm::matmul_tiled_with(&lut, &xs, &mut ColWindow::full(&mut outm), gemm::TILE_ROWS)
            });
            let t = bench(&format!("anyprec-{prec}bit b={batch} tiled"), 1, reps, || {
                gemm::matmul_tiled_with(&ap, &xs, &mut ColWindow::full(&mut outm), gemm::TILE_ROWS)
            });
            println!(
                "   anyprec-{prec}bit b={batch} vs lut-4bit ×{:.2}",
                s.mean_secs / t.mean_secs.max(1e-12)
            );
            rows.push(
                speedup_row("anyprec_plane_decode", s.mean_secs * 1e3, t.mean_secs * 1e3)
                    .with("precision", prec)
                    .with("batch", batch),
            );
        }
    }

    // -- parallel kernels: serial vs shared worker pool -------------------
    let threads = num_threads();
    println!("-- parallel kernels (pool width {threads}) --");
    // Hessian accumulation: H = X^T X with a calibration-shaped X.
    let n_cal = if fast { 256 } else { 1024 };
    let xc = Mat::randn(n_cal, d, 1.0, &mut rng);
    let tn_reps = if fast { 3 } else { 10 };
    let s = bench("matmul_tn serial", 1, tn_reps, || matmul_tn_with(&xc, &xc, 1));
    let p = bench("matmul_tn pool", 1, tn_reps, || matmul_tn(&xc, &xc));
    println!("   matmul_tn speedup ×{:.2}", s.mean_secs / p.mean_secs.max(1e-12));
    rows.push(
        speedup_row("matmul_tn", s.mean_secs * 1e3, p.mean_secs * 1e3).with("threads", threads),
    );

    // Column-sharded batched decode step at batch 8 (the serve hot loop).
    let batch = 8;
    let xs = Mat::randn(batch, d, 1.0, &mut rng);
    let mut outm = Mat::zeros(batch, d);
    let dec_reps = if fast { 5 } else { 30 };
    for (name, lin) in [
        ("uniform-4bit", &uni as &dyn LinearOp),
        ("lut-4bit", &lut as &dyn LinearOp),
    ] {
        let s = bench(&format!("batched decode {name} b={batch} serial"), 1, dec_reps, || {
            matmul_col_sharded_with(lin, &xs, &mut outm, 1)
        });
        let p = bench(&format!("batched decode {name} b={batch} pool"), 1, dec_reps, || {
            matmul_col_sharded_with(lin, &xs, &mut outm, threads)
        });
        println!(
            "   batched decode {name} speedup ×{:.2}",
            s.mean_secs / p.mean_secs.max(1e-12)
        );
        rows.push(
            speedup_row("batched_decode", s.mean_secs * 1e3, p.mean_secs * 1e3)
                .with("format", name)
                .with("batch", batch)
                .with("threads", threads),
        );
    }

    // Lane×head-parallel attention over the head-major paged KV cache:
    // batch-8 long-context decode, the serve hot loop once the linears are
    // amortized. Serial vs pool is bit-identical; only placement changes.
    let (heads, hd) = (8usize, 64usize);
    let dm = heads * hd;
    let n_pos = if fast { 128 } else { 512 };
    let batch = 8;
    let mut states: Vec<DecodeState> =
        (0..batch).map(|_| DecodeState::new(1, heads, hd)).collect();
    for st in states.iter_mut() {
        for p in 0..n_pos {
            let k: Vec<f32> = (0..dm).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..dm).map(|_| rng.normal_f32()).collect();
            st.append_kv(0, &k, &v);
            if p + 1 < n_pos {
                st.pos += 1;
            }
        }
    }
    let refs: Vec<&DecodeState> = states.iter().collect();
    let qm = Mat::randn(batch, dm, 1.0, &mut rng);
    let mut ctx = Mat::zeros(batch, dm);
    let scale = 1.0 / (hd as f32).sqrt();
    let att_reps = if fast { 5 } else { 30 };
    let s = bench(&format!("attention b={batch} ctx={n_pos} serial"), 1, att_reps, || {
        attention_batch_with(0, heads, hd, scale, &qm, &refs, &mut ctx, 1)
    });
    let p = bench(&format!("attention b={batch} ctx={n_pos} pool"), 1, att_reps, || {
        attention_batch_with(0, heads, hd, scale, &qm, &refs, &mut ctx, threads)
    });
    println!("   attention speedup ×{:.2}", s.mean_secs / p.mean_secs.max(1e-12));
    rows.push(
        speedup_row("attention", s.mean_secs * 1e3, p.mean_secs * 1e3)
            .with("batch", batch)
            .with("ctx", n_pos)
            .with("threads", threads),
    );

    // f16 KV storage: the same batch-8 long-context attention reading
    // half-width pages (decode memory traffic halves; scores widen on
    // read). Baseline is the f32 pool row above. Bytes-per-token gauges
    // come straight from the states' own accounting.
    let mut states16: Vec<DecodeState> =
        (0..batch).map(|_| DecodeState::with_dtype(1, heads, hd, KvDtype::F16)).collect();
    for st in states16.iter_mut() {
        for p in 0..n_pos {
            let k: Vec<f32> = (0..dm).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..dm).map(|_| rng.normal_f32()).collect();
            st.append_kv(0, &k, &v);
            if p + 1 < n_pos {
                st.pos += 1;
            }
        }
    }
    let refs16: Vec<&DecodeState> = states16.iter().collect();
    let f = bench(&format!("attention b={batch} ctx={n_pos} f16 kv"), 1, att_reps, || {
        attention_batch_with(0, heads, hd, scale, &qm, &refs16, &mut ctx, threads)
    });
    let tok_bytes_f32 = states[0].kv_bytes() / states[0].pos.max(1);
    let tok_bytes_f16 = states16[0].kv_bytes() / states16[0].pos.max(1);
    println!(
        "   f16 kv speedup ×{:.2} ({tok_bytes_f16} vs {tok_bytes_f32} KV bytes/token/lane)",
        p.mean_secs / f.mean_secs.max(1e-12)
    );
    rows.push(
        speedup_row("attention_kv_f16", p.mean_secs * 1e3, f.mean_secs * 1e3)
            .with("batch", batch)
            .with("ctx", n_pos)
            .with("threads", threads)
            .with("kv_bytes_per_token_f32", tok_bytes_f32)
            .with("kv_bytes_per_token_f16", tok_bytes_f16),
    );

    // -- prefill: cold vs prefix-hit over the shared-prefix KV index ------
    // A finished request donates its prompt's page-aligned (64-position)
    // KV chunks to the scheduler's prefix index; later requests with the
    // same prompt map those pages copy-on-write and start prefill after
    // the cached positions. The cold rows rerun the identical prompts
    // against a `prefix_cache: false` scheduler — tokens out are
    // bit-identical by contract, only the prefill compute changes, so the
    // ratio is the prefill work a cache hit skips. Ungated: the speedup
    // scales with prefix length, which makes a fixed floor meaningless.
    println!("-- prefill: cold vs prefix-hit (tiny preset) --");
    let (mcfg, _) = preset("tiny");
    let ps = ParamStore::init(&mcfg, &mut Rng::new(5));
    let nm = NativeModel::from_params(&ps);
    fn drive(s: &mut Scheduler<'_>, prompt: &[u32], batch: usize) -> usize {
        for _ in 0..batch {
            s.submit(prompt, 1).unwrap();
        }
        s.run_to_completion().len()
    }
    let pf_reps = if fast { 2 } else { 5 };
    for prefix in [64usize, 256] {
        // `prefix + 2` tokens: usable cached chunks are capped at
        // (prompt_len - 1) / 64, so the hit covers exactly `prefix`
        // positions and prefill still has real work (2 positions) to do.
        let prompt =
            random_prompts(mcfg.vocab, 1, prefix + 2, 40 + prefix as u64).pop().unwrap();
        for batch in [1usize, 8] {
            let scfg = |on: bool| ServeConfig {
                max_batch: 8,
                max_queued: 16,
                prefix_cache: on,
                ..ServeConfig::default()
            };
            let mut cold = Scheduler::new(&nm, scfg(false));
            let mut warm = Scheduler::new(&nm, scfg(true));
            // Donate the prompt's chunks once, outside the timed region.
            drive(&mut warm, &prompt, 1);
            let c = bench(&format!("prefill cold b={batch} prefix={prefix}"), 1, pf_reps, || {
                drive(&mut cold, &prompt, batch)
            });
            let h =
                bench(&format!("prefill prefix-hit b={batch} prefix={prefix}"), 1, pf_reps, || {
                    drive(&mut warm, &prompt, batch)
                });
            println!(
                "   prefill b={batch} prefix={prefix} hit speedup ×{:.2} ({} hits, {} prefill tokens saved)",
                c.mean_secs / h.mean_secs.max(1e-12),
                warm.prefix_hits(),
                warm.prefill_tokens_saved()
            );
            rows.push(
                speedup_row("prefix_prefill", c.mean_secs * 1e3, h.mean_secs * 1e3)
                    .with("batch", batch)
                    .with("ctx", prefix),
            );
            // The on/off bit-identity contract, spot-checked in situ.
            cold.submit(&prompt, 4).unwrap();
            warm.submit(&prompt, 4).unwrap();
            let (cf, wf) = (cold.run_to_completion(), warm.run_to_completion());
            assert_eq!(cf[0].tokens, wf[0].tokens, "prefix-cache on/off diverged");
            assert!(warm.prefix_hits() > 0, "prefix index never hit");
        }
    }

    // Machine-readable artifact (CI uploads BENCH_micro_kernels.json) —
    // written before the artifact-gated L1 section so it exists even on a
    // bare checkout.
    write_bench_json(&rows, fast, threads, d);

    // L1 kernel: artifact (Pallas xtsx lowered through interpret) vs
    // native. Needs AOT artifacts on disk; skipped otherwise.
    let model = common::bench_model();
    if !std::path::Path::new("artifacts").join(&model).join("manifest.txt").exists() {
        println!("-- L1 xtsx kernel: artifacts/{model} missing, section skipped --");
        return;
    }
    let s = common::setup(&model);
    let rt = &s.pipeline.rt;
    let bc = rt.manifest.batch;
    let n = bc.tokens();
    let dm = s.ps.cfg.d_model;
    let g = rt.manifest.groups + 1;
    let xmat = Mat::randn(n, dm, 1.0, &mut rng);
    let smat = Mat::from_fn(g, n, |_, _| rng.f32() + 0.1);
    if let Ok(artifact) = rt.artifact("xtsx_demo") {
        println!("-- L1 xtsx kernel ({n}x{dm}, g={g}) --");
        bench("xtsx artifact (Pallas interpret)", 1, if fast { 2 } else { 5 }, || {
            artifact
                .execute(&[Value::from_mat(&xmat), Value::from_mat(&smat)])
                .unwrap()
        });
        bench("xtsx native rust", 1, if fast { 2 } else { 5 }, || {
            // out[k] = X^T diag(s_k) X via scaled-copy + matmul_tn.
            (0..g)
                .map(|k| {
                    let mut xs = xmat.clone();
                    for i in 0..n {
                        let sv = smat.at(k, i);
                        for v in xs.row_mut(i) {
                            *v *= sv;
                        }
                    }
                    matmul_tn(&xmat, &xs)
                })
                .collect::<Vec<_>>()
        });
    }
}
