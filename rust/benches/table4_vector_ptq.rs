//! Paper Table 4 — weight-only vector PTQ: GPTVQ 2D, trellis (QTIP analog)
//! and trellis + GuidedQuant across bits. Target shape: trellis+GQ ≤
//! trellis, and vector methods competitive with scalar at equal bits.

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::report::{f, Table};

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let fp = s.ppl(&s.ps, "fwd_loss");
    let mut table = Table::new(
        &format!("Table 4 analog — weight-only vector PTQ ({model}); fp32 ppl {fp:.3}"),
        &["method", "bits", "avg_bits", "ppl_eval", "ppl_shift"],
    );
    for bits in [2u32, 3, 4] {
        let mut rows: Vec<(&str, QuantConfig)> = vec![
            ("gptvq2d", QuantConfig::with(QuantMethod::Gptvq2d, bits, 0)),
            ("qtip(trellis)", QuantConfig::with(QuantMethod::Trellis, bits, 0)),
            ("qtip+gquant", QuantConfig::with(QuantMethod::Trellis, bits, 4)),
        ];
        for (name, qcfg) in rows.drain(..) {
            let layers = s.pipeline.quantize(&s.ps, &s.stats, &qcfg).unwrap();
            let qps = s.apply(&layers);
            table.row(vec![
                name.into(),
                bits.to_string(),
                f(s.pipeline.avg_bits(&layers), 2),
                f(s.ppl(&qps, "fwd_loss"), 3),
                f(s.ppl_shift(&qps), 3),
            ]);
        }
    }
    table.print();
    table.save_csv("table4_vector_ptq").unwrap();
}
