//! Paper Tables 8 & 9 — cost accounting: wall-clock of the quantization
//! phase per method/bits/groups, plus Hessian-caching time and cache size
//! (our single-node CPU analog of their GPU-hours and disk GiB).

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::report::{f, Table};
use guidedquant::util::human_bytes;

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);

    // Table 9 analog: Hessian caching cost (recompute once, timed).
    let t = std::time::Instant::now();
    let stats = s.pipeline.calib(&s.ps, true).unwrap();
    let calib_secs = t.elapsed().as_secs_f64();
    let cache_bytes = s.pipeline.metrics.get("hessian_cache_bytes") as u64;
    println!(
        "Table 9 analog: hessian caching {calib_secs:.2}s over {} batches, cache {} (g={})",
        stats.batches,
        human_bytes(cache_bytes),
        stats.groups
    );

    // Table 8 analog: quantization wall-time per method × bits × g.
    let mut table = Table::new(
        &format!("Table 8 analog — quantization cost ({model})"),
        &["method", "bits", "groups", "secs"],
    );
    for bits in [2u32, 4] {
        for (name, method, groups) in [
            ("lnq", QuantMethod::Lnq, 0usize),
            ("lnq+gq(g=1)", QuantMethod::Lnq, 1),
            ("lnq+gq(g=2)", QuantMethod::Lnq, 2),
            ("lnq+gq(g=4)", QuantMethod::Lnq, 4),
            ("qtip", QuantMethod::Trellis, 0),
            ("qtip+gq(g=4)", QuantMethod::Trellis, 4),
        ] {
            let t = std::time::Instant::now();
            let _ = s
                .pipeline
                .quantize(&s.ps, &stats, &QuantConfig::with(method, bits, groups))
                .unwrap();
            table.row(vec![
                name.into(),
                bits.to_string(),
                groups.to_string(),
                f(t.elapsed().as_secs_f64(), 2),
            ]);
        }
    }
    table.print();
    table.save_csv("table8_cost").unwrap();
}
