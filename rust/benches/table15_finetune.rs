//! Paper Table 15 — post-PTQ end-to-end fine-tuning (PV-tuning-lite
//! cascade; see quant::finetune for the substitution note). Rows:
//! SqueezeLLM and LNQ+GQ at 2/3 bits, before and after fine-tuning.

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::data::Split;
use guidedquant::quant::cd::CdConfig;
use guidedquant::quant::finetune::{cascade_finetune, TunableLayer};
use guidedquant::report::{f, Table};

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let tune_tokens = s.pipeline.corpus.tokens(Split::Train, 256);

    let mut table = Table::new(
        &format!("Table 15 analog — end-to-end fine-tuning after PTQ ({model})"),
        &["method", "bits", "ppl_before_ft", "ppl_after_ft"],
    );
    for bits in [2u32, 3] {
        for (name, method, groups) in [
            ("squeezellm", QuantMethod::SqueezeLlm, 0usize),
            ("lnq+gquant", QuantMethod::Lnq, 4),
        ] {
            let layers = s
                .pipeline
                .quantize(&s.ps, &s.stats, &QuantConfig::with(method, bits, groups))
                .unwrap();
            let qps = s.apply(&layers);
            let before = s.ppl(&qps, "fwd_loss");
            // Build tunable layers (codes + codebooks required).
            let mut tunable: Vec<TunableLayer> = layers
                .iter()
                .filter_map(|l| {
                    Some(TunableLayer {
                        name: l.name.clone(),
                        codes: l.result.codes.clone()?,
                        codebooks: l.result.codebooks.clone()?,
                        d_in: l.result.w_hat.rows,
                    })
                })
                .collect();
            let after = if tunable.len() == layers.len() {
                let tuned =
                    cascade_finetune(&s.ps, &mut tunable, &tune_tokens, 1, CdConfig::default())
                        .unwrap();
                s.ppl(&tuned, "fwd_loss")
            } else {
                f64::NAN
            };
            table.row(vec![name.into(), bits.to_string(), f(before, 3), f(after, 3)]);
        }
    }
    table.print();
    table.save_csv("table15_finetune").unwrap();
}
