//! Paper Table 1 — the headline summary: one representative row per
//! quantization family at the most extreme setting (2 bits / W4A4KV4),
//! each with and without GuidedQuant.

#[path = "common.rs"]
mod common;

use guidedquant::cfg::{QuantConfig, QuantMethod};
use guidedquant::report::{f, Table};

fn main() {
    let model = common::bench_model();
    let s = common::setup(&model);
    let fp = s.ppl(&s.ps, "fwd_loss");
    let mut table = Table::new(
        &format!("Table 1 analog — headline ({model})"),
        &["type", "method", "bits", "ppl"],
    );
    table.row(vec!["-".into(), "original(fp32)".into(), "32".into(), f(fp, 3)]);

    let mut scalar = |name: &str, method: QuantMethod, groups: usize| {
        let layers = s
            .pipeline
            .quantize(&s.ps, &s.stats, &QuantConfig::with(method, 2, groups))
            .unwrap();
        let ppl = s.ppl(&s.apply(&layers), "fwd_loss");
        table.row(vec!["weight-only scalar".into(), name.into(), "2".into(), f(ppl, 3)]);
    };
    scalar("squeezellm", QuantMethod::SqueezeLlm, 0);
    scalar("lnq", QuantMethod::Lnq, 0);
    scalar("lnq+gquant", QuantMethod::Lnq, 4);

    for (name, groups) in [("qtip(trellis)", 0usize), ("qtip+gquant", 4)] {
        let layers = s
            .pipeline
            .quantize(&s.ps, &s.stats, &QuantConfig::with(QuantMethod::Trellis, 2, groups))
            .unwrap();
        let ppl = s.ppl(&s.apply(&layers), "fwd_loss");
        table.row(vec!["weight-only vector".into(), name.into(), "2".into(), f(ppl, 3)]);
    }

    // W&A row: GPTQ W4 through the A4KV4 artifact, ± GQ.
    let fp_qa = s.ppl(&s.ps, "fwd_loss_qa4kv4");
    table.row(vec!["weight+activation".into(), "fp-w/A4KV4".into(), "W32A4KV4".into(), f(fp_qa, 3)]);
    for (name, groups) in [("gptq/A4KV4", 0usize), ("gptq+gquant/A4KV4", 4)] {
        let layers = s
            .pipeline
            .quantize(&s.ps, &s.stats, &QuantConfig::with(QuantMethod::Gptq, 4, groups))
            .unwrap();
        let ppl = s.ppl(&s.apply(&layers), "fwd_loss_qa4kv4");
        table.row(vec!["weight+activation".into(), name.into(), "W4A4KV4".into(), f(ppl, 3)]);
    }
    table.print();
    table.save_csv("table1_headline").unwrap();
}
