//! Shared setup for the paper-table benches: a trained checkpoint and
//! calibration statistics, cached under target/benchres/cache so every
//! bench binary reuses them instead of retraining.

#![allow(dead_code)]

use guidedquant::cfg::{preset, PipelineConfig};
use guidedquant::coordinator::{Pipeline, QuantizedLayer};
use guidedquant::data::Split;
use guidedquant::fisher::CalibStats;
use guidedquant::model::ParamStore;

pub struct Setup {
    pub pipeline: Pipeline,
    pub ps: ParamStore,
    pub stats: CalibStats,
}

/// Default bench model; override with GQ_BENCH_MODEL=small|base.
pub fn bench_model() -> String {
    std::env::var("GQ_BENCH_MODEL").unwrap_or_else(|_| "tiny".to_string())
}

fn train_steps(model: &str) -> usize {
    match model {
        "tiny" => 600,
        "small" => 500,
        _ => 150,
    }
}

/// Build (or load cached) trained params + calib stats for `model`.
pub fn setup(model: &str) -> Setup {
    let cache_dir = std::path::PathBuf::from("target/benchres/cache");
    std::fs::create_dir_all(&cache_dir).unwrap();
    let cfg = PipelineConfig {
        model: model.to_string(),
        out_dir: cache_dir.to_str().unwrap().to_string(),
        train_steps: train_steps(model),
        calib_batches: if model == "tiny" { 6 } else { 8 },
        eval_batches: if model == "tiny" { 8 } else { 12 },
        ..Default::default()
    };
    let pipeline = Pipeline::new(cfg).expect("artifacts missing — run `make artifacts`");
    let ckpt = cache_dir.join(format!("trained_{model}.gqtb"));
    let (model_cfg, _) = preset(model);
    let ps = if ckpt.exists() {
        ParamStore::load(&model_cfg, &ckpt).unwrap()
    } else {
        let mut ps = pipeline.init_params();
        eprintln!("[bench-setup] training {model} for {} steps ...", pipeline.cfg.train_steps);
        pipeline.train(&mut ps, pipeline.cfg.train_steps, 50).unwrap();
        ps.save(&ckpt).unwrap();
        ps
    };
    let stats = pipeline.calib(&ps, false).unwrap();
    Setup { pipeline, ps, stats }
}

impl Setup {
    pub fn ppl(&self, ps: &ParamStore, artifact: &str) -> f64 {
        self.pipeline.perplexity(ps, Split::Eval, artifact).unwrap()
    }

    pub fn ppl_shift(&self, ps: &ParamStore) -> f64 {
        self.pipeline.perplexity(ps, Split::EvalShift, "fwd_loss").unwrap()
    }

    pub fn apply(&self, layers: &[QuantizedLayer]) -> ParamStore {
        self.pipeline.apply_quantized(&self.ps, layers)
    }
}
